"""Mixed-precision weight-stationary GEMMs (w8/w4/w2/w1 x a16, and w8a8).

Kratos' precision axis: on the FPGA, a b-bit constant-coefficient multiplier
costs ~b^2 LUTs, so area drops super-linearly with bits. On the TPU the wins
are restated as:

  * weight HBM traffic ∝ bits (sub-byte codes are bit-packed into int8 lanes
    and unpacked in-register inside the kernel — the memory roofline term
    drops linearly with bits);
  * w8a8 runs the MXU in int8 mode at 2x the bf16 MAC rate (compute term);
  * dequantization is fused: per-output-channel scales are applied once per
    output tile at accumulator flush, never materializing a float weight in
    HBM.

Packing matches core.quantize: codes packed along the reduction axis,
little-endian fields within each byte, two's complement (sign bit for 1-bit).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat as _compat

from repro.core import quantize as qz


def _unpack_tile(wq: jnp.ndarray, bits: int) -> jnp.ndarray:
    """int8[(bk/vpb), bn] packed -> int8[bk, bn] codes (in-kernel)."""
    if bits == 8:
        return wq
    vpb = qz.VALUES_PER_BYTE[bits]
    mask = (1 << bits) - 1
    sign = 1 << (bits - 1)
    pu = wq.astype(jnp.uint8)
    fields = []
    for i in range(vpb):
        f = (pu >> jnp.uint8(i * bits)) & mask
        if bits == 1:
            f = f.astype(jnp.int32) * 2 - 1
        else:
            f = (f.astype(jnp.int32) ^ sign) - sign
        fields.append(f.astype(jnp.int8))
    tile = jnp.stack(fields, axis=1)                # (bk/vpb, vpb, bn)
    return tile.reshape(wq.shape[0] * vpb, wq.shape[1])


def _wq_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, n_kb: int, bits: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_tile(w_ref[...], bits)
    acc_ref[...] += jnp.dot(
        x_ref[...], codes.astype(x_ref.dtype),
        preferred_element_type=jnp.float32)

    @pl.when(t == n_kb - 1)
    def _flush():
        # per-output-channel dequant, fused at flush time
        o_ref[...] = (acc_ref[...] * s_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def quant_matmul(
    x: jnp.ndarray,              # (m, n) float
    qt: qz.QuantizedTensor,      # packed (n/vpb, p) + scale (p,)
    *,
    bm: int = 128,
    bk: int = 128,               # in *unpacked* k elements
    bn: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    m, n = x.shape
    n_full, p = qt.shape
    assert n == n_full, (x.shape, qt.shape)
    vpb = qz.VALUES_PER_BYTE[qt.bits]
    if bk % vpb:
        raise ValueError(f"bk={bk} must be divisible by values-per-byte={vpb}")
    for name, dim, b in (("n", n, bk), ("p", p, bn)):
        if dim % b:
            raise ValueError(f"{name}={dim} not divisible by its block {b}")
    # skinny-m path (decode: m = n_slots); pad rows, slice the result back.
    bm = _compat.skinny_bm(m, bm, x.dtype)
    x, m_orig = _compat.pad_rows(x, bm, "quant_matmul")
    m = x.shape[0]
    grid = (m // bm, p // bn, n // bk)
    kernel = functools.partial(_wq_kernel, n_kb=n // bk, bits=qt.bits)
    scale2d = qt.scale.reshape(1, p)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk // vpb, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, p), x.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, qt.data, scale2d)
    return out if m == m_orig else out[:m_orig]


def _w8a8_kernel(xq_ref, xs_ref, w_ref, s_ref, o_ref, acc_ref, *, n_kb: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        xq_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(t == n_kb - 1)
    def _flush():
        deq = (acc_ref[...].astype(jnp.float32)
               * xs_ref[...].astype(jnp.float32)
               * s_ref[...].astype(jnp.float32))
        o_ref[...] = deq.astype(o_ref.dtype)


def quant_matmul_w8a8(
    x: jnp.ndarray,
    qt: qz.QuantizedTensor,
    *,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Int8 x int8 GEMM at 2x MXU rate: activations quantized per-row on the
    fly (outside the kernel, fusable), int32 accumulation, joint dequant."""
    assert qt.bits == 8
    m, n = x.shape
    _, p = qt.shape
    for name, dim, b in (("n", n, bk), ("p", p, bn)):
        if dim % b:
            raise ValueError(f"{name}={dim} not divisible by its block {b}")
    xq, xs = qz.quantize_activations_int8(x)
    # skinny-m path: pad AFTER activation quantization (an all-zero pad row
    # would otherwise hit the per-row scale computation); int8 sublane is 32.
    bm = _compat.skinny_bm(m, bm, xq.dtype)
    xq, m_orig = _compat.pad_rows(xq, bm, "quant_matmul_w8a8")
    if xq.shape[0] != m:
        xs = jnp.pad(xs, ((0, xq.shape[0] - m), (0, 0)))
    m = xq.shape[0]
    grid = (m // bm, p // bn, n // bk)
    kernel = functools.partial(_w8a8_kernel, n_kb=n // bk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bm, 1), lambda i, j, t: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((1, bn), lambda i, j, t: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        out_shape=jax.ShapeDtypeStruct((m, p), x.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, xs, qt.data, qt.scale.reshape(1, p))
    return out if m == m_orig else out[:m_orig]


def _bsr_wq_kernel(idx_ref, x_ref, b_ref, s_ref, o_ref, acc_ref,
                   *, nnz: int, bits: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_tile(b_ref[0, 0], bits)
    acc_ref[...] += jnp.dot(
        x_ref[...], codes.astype(x_ref.dtype),
        preferred_element_type=jnp.float32)

    @pl.when(t == nnz - 1)
    def _flush():
        o_ref[...] = (acc_ref[...] * s_ref[0].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def bsr_quant_matmul(
    x: jnp.ndarray,            # (m, n)
    qblocks: jnp.ndarray,      # int8[n_pb, nnz, bk/vpb, bn]
    scales: jnp.ndarray,       # f32[n_pb, bn]
    indices: jnp.ndarray,      # int32[n_pb, nnz]
    bits: int,
    *,
    bm: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Kratos point-3 kernel: pruning x quantization compounded.

    Skips zero blocks via scalar-prefetch indices AND streams bit-packed
    weights: weight traffic ∝ (1 - sparsity) * bits / 16 vs dense bf16.
    """
    m, n = x.shape
    n_pb, nnz, bkp, bn = qblocks.shape
    vpb = qz.VALUES_PER_BYTE[bits]
    bk = bkp * vpb
    if n % bk:
        raise ValueError(f"n={n} not divisible by block k-extent {bk}")
    # skinny-m path (decode: m = n_slots); pad rows, slice the result back.
    bm = _compat.skinny_bm(m, bm, x.dtype)
    x, m_orig = _compat.pad_rows(x, bm, "bsr_quant_matmul")
    m = x.shape[0]
    grid = (m // bm, n_pb, nnz)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t, idx: (i, idx[j, t])),
            pl.BlockSpec((1, 1, bkp, bn), lambda i, j, t, idx: (j, t, 0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, t, idx: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t, idx: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(_bsr_wq_kernel, nnz=nnz, bits=bits)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n_pb * bn), x.dtype),
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(indices, jnp.int32), x, qblocks, scales)
    return out if m == m_orig else out[:m_orig]
