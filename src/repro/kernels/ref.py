"""Pure-jnp reference oracles for every Pallas kernel.

These are the "Modelsim ground truth" of the paper's workflow (§III-D): each
Pallas kernel is validated against the oracle here over shape/dtype/sparsity
sweeps (tests/test_kernels.py). They are also the XLA execution path used by
the 512-device dry-run (Pallas lowers to TPU-only custom calls, and this
container's backend is CPU) — crucially, the *tree* (gathered block) oracle
performs only the nonzero-block FLOPs, so `compiled.cost_analysis()` sees the
same linear-in-(1-sparsity) compute reduction the TPU kernel achieves.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as qz


# ---------------------------------------------------------------------------
# GEMM family
# ---------------------------------------------------------------------------

# Projection-dot accumulation type. f32 matches MXU accumulate; setting bf16
# (dryrun --bf16-reduce) makes GSPMD's row-parallel psums run on bf16 wires —
# the standard TPU practice for activation/grad reductions (§Perf iteration).
_DOT_ACCUM = jnp.float32


def set_dot_accum(dtype) -> None:
    global _DOT_ACCUM
    _DOT_ACCUM = jnp.dtype(dtype)


def dense_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """The 'gemms' (weight-stationary systolic) analogue: dense compute
    regardless of how many weights are zero."""
    return jnp.dot(x, w, preferred_element_type=_DOT_ACCUM).astype(x.dtype)


def bsr_matmul_ref(x: jnp.ndarray, blocks: jnp.ndarray, indices) -> jnp.ndarray:
    """The 'gemmt' (multiply-adder tree) analogue, oracle form.

    x: (m, n); blocks: (n_pb, nnz, bk, bn); indices: int[n_pb, nnz].
    Gathers the x k-blocks referenced by each output block and contracts —
    FLOPs = 2 * m * (nnz * bk) * (n_pb * bn) = dense * (1 - sparsity).
    """
    m, n = x.shape
    n_pb, nnz, bk, bn = blocks.shape
    xb = x.reshape(m, n // bk, bk)
    idx = jnp.asarray(indices)
    xg = jnp.take(xb, idx, axis=1)            # (m, n_pb, nnz, bk)
    y = jnp.einsum("mjtk,jtkn->mjn", xg, blocks,
                   preferred_element_type=jnp.float32)
    return y.reshape(m, n_pb * bn).astype(x.dtype)


def bsr_matmul_scan_ref(x: jnp.ndarray, blocks: jnp.ndarray, indices) -> jnp.ndarray:
    """Memory-light tree form: sequential over output-column blocks.

    Peak extra memory is one gathered (m, nnz, bk) slab instead of n_pb of
    them; HBM traffic matches the weight-stationary kernel's natural x re-read
    per output tile. Used inside full models (dry-run path).
    """
    m, n = x.shape
    n_pb, nnz, bk, bn = blocks.shape
    xb = x.reshape(m, n // bk, bk)
    idx = jnp.asarray(indices)

    def one_block(carry, args):
        blk, ix = args                         # (nnz, bk, bn), (nnz,)
        xg = jnp.take(xb, ix, axis=1)          # (m, nnz, bk)
        y = jnp.einsum("mtk,tkn->mn", xg, blk,
                       preferred_element_type=jnp.float32)
        return carry, y.astype(x.dtype)

    _, ys = jax.lax.scan(one_block, None, (blocks, idx))
    return jnp.moveaxis(ys, 0, 1).reshape(m, n_pb * bn)


def quant_matmul_ref(x: jnp.ndarray, qt: qz.QuantizedTensor) -> jnp.ndarray:
    """Weight-only quantized GEMM (w{8,4,2,1}a16): unpack, dequant, matmul."""
    w = qz.dequantize(qt, dtype=x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def quant_matmul_w8a8_ref(x: jnp.ndarray, qt: qz.QuantizedTensor) -> jnp.ndarray:
    """Fully-quantized int8 GEMM: dynamic per-row act quant, int32 accumulate."""
    assert qt.bits == 8
    xq, xs = qz.quantize_activations_int8(x)
    acc = jax.lax.dot_general(
        xq, qt.data, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * xs * qt.scale[None, :]).astype(x.dtype)


def bsr_quant_matmul_ref(x, qblocks, scales, indices, bits) -> jnp.ndarray:
    """Sparse + quantized tree GEMM (Kratos point 3: pruning + quantization).

    qblocks: int8[n_pb, nnz, bk // vpb, bn] packed codes;
    scales:  f32[n_pb, bn] per output channel.
    """
    n_pb, nnz, bkp, bn = qblocks.shape
    vpb = qz.VALUES_PER_BYTE[bits]
    flat = qblocks.reshape(n_pb * nnz, bkp, bn)
    codes = jax.vmap(lambda b: qz.unpack_codes(b, bits))(flat)
    blocks = codes.reshape(n_pb, nnz, bkp * vpb, bn).astype(x.dtype)
    y = bsr_matmul_ref(x, blocks, indices)
    return (y.reshape(x.shape[0], n_pb, bn) * scales[None].astype(x.dtype)
            ).reshape(x.shape[0], n_pb * bn)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def attention_ref(
    q: jnp.ndarray,            # (b, h, sq, d)
    k: jnp.ndarray,            # (b, h, skv, d)
    v: jnp.ndarray,            # (b, h, skv, d)
    *,
    causal: bool = True,
    window: Optional[int] = None,     # sliding-window size (None = unbounded)
    softcap: Optional[float] = None,  # gemma2-style logit soft-capping
    q_offset: int = 0,                # absolute position of q[0] (decode)
    scale: Optional[float] = None,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    skv = k.shape[2]
    scale = (d ** -0.5) if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def paged_view(pages: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Contiguous per-slot view of a page-major K/V leaf.

    pages: (n_pages, h_kv, P, d); table: (b, pp) int32 page ids. Returns
    (b, h_kv, pp * P, d) — slot b's logical KV stream in position order.
    Table entries pointing at the sink page (page 0) yield garbage rows that
    the caller's position mask must exclude.
    """
    g = pages[table]                          # (b, pp, h_kv, P, d)
    g = jnp.moveaxis(g, 1, -3)                # (b, h_kv, pp, P, d)
    b, h_kv = g.shape[0], g.shape[1]
    return g.reshape(b, h_kv, g.shape[2] * g.shape[3], g.shape[4])


def paged_attention_ref(
    q: jnp.ndarray,            # (b, h, sq, d)
    k_pages: jnp.ndarray,      # (n_pages, h_kv, P, d)
    v_pages: jnp.ndarray,      # (n_pages, h_kv, P, d)
    table: jnp.ndarray,        # (b, pp) int32 page ids
    last: jnp.ndarray,         # (b,) int32 absolute position of q[:, -1]
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Oracle for the page-table-native decode attention kernel.

    Causal decode attention where K/V stream straight out of the page-major
    store via `table` and per-slot validity comes from `last` (the vector
    analogue of attention_ref's scalar q_offset): position j is live for
    query row i iff j <= last[b] - (sq - 1) + i. Sink-page rows land at
    positions past `last` and are masked out by the same test.
    """
    b, h, sq, d = q.shape
    h_kv = k_pages.shape[1]
    scale = (d ** -0.5) if scale is None else scale
    k = paged_view(k_pages, table)
    v = paged_view(v_pages, table)
    if h != h_kv:
        g = h // h_kv
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    skv = k.shape[2]
    qpos = (last[:, None] - (sq - 1) + jnp.arange(sq)[None, :])  # (b, sq)
    kpos = jnp.arange(skv)
    mask = kpos[None, None, :] <= qpos[:, :, None]               # (b, sq, skv)
    if window is not None:
        mask &= kpos[None, None, :] > qpos[:, :, None] - window
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
