"""Trace-time event-counter registry with scoped reset.

Several modules record *trace-time* evidence that a particular code path
actually compiled — `kernels.pallas_compat.SKINNY_M_EVENTS` (a GEMM padded
its skinny row dim), `PAGED_ATTN_EVENTS` (the paged-attention decode path
dispatched), `serve.paging.GATHER_EVENTS` (a legacy gather/scatter
materialized the slab view). Historically each was a bare module-global
list that tests `.clear()`ed by hand, which leaks events across
parallel/reordered tests: a test that forgets to clear (or that runs while
another module traces) inherits someone else's events.

This module promotes them into ONE registry of named `EventList`s. The
lists are ordinary `list` subclasses, so every existing call site —
`.append(...)`, `.clear()`, `list(...)`, truthiness — keeps working, and
the historical module-global names remain as aliases **of the same
objects**. What the registry adds:

  * `REGISTRY.scoped(...)` — a context manager that snapshots the named
    lists (all of them by default), clears them IN PLACE, runs the body,
    and restores the prior contents in place on exit. Tests wrap their
    trace-and-assert block in it and can neither see events from earlier
    tests nor leak their own into later ones.
  * `REGISTRY.reset(...)` / `REGISTRY.snapshot()` — explicit clear and a
    name -> tuple copy of current contents, for benches that want counts
    without the context-manager shape.

In-place mutation (never rebinding) is the load-bearing detail: aliases in
other modules (`ops.SKINNY_M_EVENTS`, `from ... import GATHER_EVENTS`)
stay live because the identity of each list never changes.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Iterator, List, Tuple


class EventList(list):
    """A named, registry-owned trace-time event list.

    Identical to `list` for every caller; the extra `name` exists only so
    diagnostics can say which stream an assertion is about.
    """

    def __init__(self, name: str):
        super().__init__()
        self.name = name

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"EventList({self.name!r}, {list(self)!r})"


class EventRegistry:
    """Named event-list store; all mutation is in place (aliases stay live)."""

    def __init__(self) -> None:
        self._lists: Dict[str, EventList] = {}
        self._lock = threading.Lock()

    def event_list(self, name: str) -> EventList:
        """Get-or-create the named list. Repeat calls return the SAME
        object, which is what makes module-global aliasing safe."""
        with self._lock:
            if name not in self._lists:
                self._lists[name] = EventList(name)
            return self._lists[name]

    def names(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._lists))

    def reset(self, *names: str) -> None:
        """Clear the named lists (all registered lists when none given)."""
        for n in names or self.names():
            self.event_list(n).clear()

    def snapshot(self) -> Dict[str, Tuple]:
        """name -> tuple copy of current contents (counts for benches)."""
        return {n: tuple(self.event_list(n)) for n in self.names()}

    @contextlib.contextmanager
    def scoped(self, *names: str) -> Iterator[Dict[str, EventList]]:
        """Snapshot + clear the named lists (default: all) in place; restore
        the prior contents in place on exit. Yields name -> list so the body
        can assert on exactly the events IT traced."""
        use = names or self.names()
        stash: Dict[str, List] = {}
        for n in use:
            lst = self.event_list(n)
            stash[n] = list(lst)
            lst.clear()
        try:
            yield {n: self.event_list(n) for n in use}
        finally:
            for n in use:
                lst = self.event_list(n)
                lst.clear()
                lst.extend(stash[n])


REGISTRY = EventRegistry()
