"""The Kratos benchmark design space (paper Tables I & II) as config objects.

Eight kernels — {gemmt, gemms} x {row-parallel, fully-unrolled} and
{conv1d, conv2d} x {pixelwise, row-parallel, fully-unrolled} — each in a
Small and Large variant, swept over 10 sparsity levels (0 .. 0.9) and 4
precisions (8/4/2/1-bit), exactly the paper's §IV-B evaluation grid.

`instantiate()` builds runnable (params, inputs, fn) plus the analytic
resource model used by the figure benchmarks:

  * effective MACs / weight bytes  (the 'ALM utilization' analogue),
  * ops-per-invocation by unroll factor (the Table-I throughput column),
  * roofline latency on the target chip (compute vs memory bound).

The microbenchmarks use block granularity bk=bn=1 in the reference path —
true element-level sparsity, matching the paper's FPGA granularity; the
LM-framework integration uses hardware-tile granularity (see core.kratos and
the Table-III tile sweep that bridges the two).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import conv as kconv
from repro.core import kratos as kr

SPARSITIES = tuple(round(0.1 * i, 1) for i in range(10))   # 0.0 .. 0.9
PRECISIONS = (8, 4, 2, 1)


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    name: str                    # e.g. 'gemmt-RP-S'
    kernel: str                  # gemmt | gemms | conv1d | conv2d
    unroll: str                  # pixelwise | row | full
    size: str                    # S | L
    # GEMM: (m, n, p). Conv: input (Iw[,Ih],Ic), filter (Fw[,Fh]), Oc.
    dims: Dict[str, int] = dataclasses.field(default_factory=dict)
    sparsity: float = 0.0
    bits: Optional[int] = None
    bk: int = 1                  # element-granular by default (FPGA parity)
    bn: int = 1

    def kratos_spec(self) -> kr.KratosSpec:
        impl = "systolic" if self.kernel == "gemms" else "tree"
        return kr.KratosSpec(sparsity=self.sparsity, bits=self.bits, impl=impl,
                             unroll=self.unroll, bk=self.bk, bn=self.bn,
                             seed=17)

    # --- analytic resource model -------------------------------------------
    def gemm_dims(self) -> Tuple[int, int, int]:
        d = self.dims
        if self.kernel in ("gemmt", "gemms"):
            return d["m"], d["n"], d["p"]
        if self.kernel == "conv1d":
            ow = d["iw"] - d["fw"] + 1
            return ow, d["fw"] * d["ic"], d["oc"]
        ow, oh = d["iw"] - d["fw"] + 1, d["ih"] - d["fh"] + 1
        return ow * oh, d["fw"] * d["fh"] * d["ic"], d["oc"]

    def ops_per_invocation(self) -> int:
        """MACs per 'cycle' under the spec's unroll factor (Table I column)."""
        m, n, p = self.gemm_dims()
        if self.unroll == "full":
            return m * n * p
        if self.unroll == "row":
            if self.kernel == "conv2d":
                ow = self.dims["iw"] - self.dims["fw"] + 1
                return ow * n * p
            return n * p                     # one GEMM row / one conv row
        return n * p                         # pixelwise: one output pixel

    def resource_report(self) -> Dict[str, float]:
        m, n, p = self.gemm_dims()
        return kr.cost_report(n, p, self.kratos_spec(), m=m)


def _mk(name, kernel, unroll, size, **dims) -> KernelSpec:
    return KernelSpec(name=name, kernel=kernel, unroll=unroll, size=size,
                      dims=dims)


# Paper Table II, verbatim sizes (conv shapes read per the Table-II format
# row: input Iw x Ih x Ic, filter Fw x Fh, output channels Oc; conv1d uses
# Ih = Fh = 1).
TABLE_II: Tuple[KernelSpec, ...] = (
    _mk("gemmt-RP-S", "gemmt", "row", "S", m=32, n=32, p=32),
    _mk("gemmt-RP-L", "gemmt", "row", "L", m=128, n=128, p=128),
    _mk("gemmt-FU-S", "gemmt", "full", "S", m=16, n=16, p=16),
    _mk("gemmt-FU-L", "gemmt", "full", "L", m=32, n=32, p=32),
    _mk("gemms-RP-S", "gemms", "row", "S", m=16, n=16, p=16),
    _mk("gemms-RP-L", "gemms", "row", "L", m=128, n=128, p=128),
    _mk("conv1d-PW-S", "conv1d", "pixelwise", "S", iw=32, ic=64, fw=3, oc=64),
    _mk("conv1d-PW-L", "conv1d", "pixelwise", "L", iw=32, ic=64, fw=3, oc=128),
    _mk("conv1d-FU-S", "conv1d", "full", "S", iw=32, ic=8, fw=3, oc=8),
    _mk("conv1d-FU-L", "conv1d", "full", "L", iw=32, ic=16, fw=3, oc=16),
    _mk("conv2d-PW-S", "conv2d", "pixelwise", "S", iw=25, ih=25, ic=32, fw=3, fh=3, oc=64),
    _mk("conv2d-PW-L", "conv2d", "pixelwise", "L", iw=25, ih=25, ic=64, fw=3, fh=3, oc=64),
    _mk("conv2d-RP-S", "conv2d", "row", "S", iw=8, ih=8, ic=8, fw=3, fh=3, oc=8),
    _mk("conv2d-RP-L", "conv2d", "row", "L", iw=8, ih=8, ic=16, fw=3, fh=3, oc=16),
    _mk("conv2d-FU-S", "conv2d", "full", "S", iw=8, ih=8, ic=4, fw=3, fh=3, oc=4),
    _mk("conv2d-FU-L", "conv2d", "full", "L", iw=8, ih=8, ic=8, fw=3, fh=3, oc=8),
)

BY_NAME = {s.name: s for s in TABLE_II}


def sweep(base: KernelSpec, sparsities=SPARSITIES,
          precisions=(None,) + PRECISIONS) -> List[KernelSpec]:
    """The paper's batch-job grid for one kernel."""
    out = []
    for s, b in itertools.product(sparsities, precisions):
        out.append(dataclasses.replace(base, sparsity=s, bits=b))
    return out


def instantiate(spec: KernelSpec, key=None, batch: int = 1):
    """Build (params, inputs, fn) for a spec. fn(params, x) -> y."""
    key = jax.random.PRNGKey(0) if key is None else key
    kspec = spec.kratos_spec()
    d = spec.dims
    if spec.kernel in ("gemmt", "gemms"):
        params = kr.init(key, d["n"], d["p"], kspec)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch * d["m"], d["n"]))

        def fn(p, xx):
            return kr.apply(p, xx, kspec, backend="ref")
        return params, x, fn
    if spec.kernel == "conv1d":
        params = kconv.conv1d_init(key, d["fw"], d["ic"], d["oc"], kspec)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, d["iw"], d["ic"]))
        fw = int(params.pop("fw"))          # static under jit

        def fn(p, xx):
            return kconv.conv1d(dict(p, fw=fw), xx, kspec, backend="ref")
        return params, x, fn
    params = kconv.conv2d_init(key, d["fw"], d["fh"], d["ic"], d["oc"], kspec)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (batch, d["iw"], d["ih"], d["ic"]))
    fw, fh = int(params.pop("fw")), int(params.pop("fh"))

    def fn(p, xx):
        return kconv.conv2d(dict(p, fw=fw, fh=fh), xx, kspec, backend="ref")
    return params, x, fn
