"""Balanced block-sparsity: the TPU-native adaptation of Kratos' fine-grained
unstructured sparsity.

On the FPGA, Kratos embeds weights into LUTs and lets synthesis delete
zero-weight MACs one by one. On a TPU the minimum granule the hardware rewards
is a tile (the VPU lane group is (8,128), the MXU is (128,128)), so the finest
*profitable* sparsity is block sparsity. We use **balanced** block sparsity:
every output-column block keeps exactly the same number of nonzero k-blocks
(`nnz`), with block positions drawn from a seeded shuffle — mirroring the
paper's "generate the desired amount of non-zero elements and randomly shuffle
their location" (§III-D), while keeping the compute grid static, which is the
TPU equivalent of a synthesizable circuit.

Layout conventions
------------------
A weight is ``w: (n_in, n_out)`` used as ``y = x @ w``. Blocks tile
``n_in`` into ``n_kb = n_in // bk`` k-blocks and ``n_out`` into
``n_pb = n_out // bn`` output-column blocks. A plan stores, for each output
block ``j``, the sorted k-block indices that are nonzero:

    plan.indices: int32[n_pb, nnz]        (static numpy at trace time)
    packed blocks: [n_pb, nnz, bk, bn]    (gathered weight data)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BlockSparsePlan:
    """Static description of a balanced block-sparse weight."""

    n_in: int
    n_out: int
    bk: int
    bn: int
    nnz: int                 # nonzero k-blocks per output-column block
    indices: np.ndarray      # int32[n_pb, nnz], sorted along axis -1
    seed: int

    @property
    def n_kb(self) -> int:
        return self.n_in // self.bk

    @property
    def n_pb(self) -> int:
        return self.n_out // self.bn

    @property
    def sparsity(self) -> float:
        """Fraction of weight *blocks* (== weight elements) that are zero."""
        return 1.0 - self.nnz / self.n_kb

    @property
    def dense_flops_fraction(self) -> float:
        """FLOPs of the tree (gathered) implementation relative to dense."""
        return self.nnz / self.n_kb

    def __repr__(self) -> str:  # keep short: numpy array spam otherwise
        return (
            f"BlockSparsePlan({self.n_in}x{self.n_out}, block={self.bk}x{self.bn}, "
            f"nnz={self.nnz}/{self.n_kb}, sparsity={self.sparsity:.3f}, seed={self.seed})"
        )


def nnz_for_sparsity(n_kb: int, sparsity: float) -> int:
    """Number of kept k-blocks per output block for a target sparsity.

    Clamped to [1, n_kb]: a fully-zero layer is degenerate (the paper sweeps
    sparsity only up to 0.9).
    """
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0, 1), got {sparsity}")
    return max(1, min(n_kb, int(round((1.0 - sparsity) * n_kb))))


def make_plan(
    n_in: int,
    n_out: int,
    *,
    bk: int = 128,
    bn: int = 128,
    sparsity: float = 0.0,
    seed: int = 0,
) -> BlockSparsePlan:
    """Build a balanced block-sparse plan with seeded-shuffled block positions."""
    if n_in % bk:
        raise ValueError(f"n_in={n_in} not divisible by bk={bk}")
    if n_out % bn:
        raise ValueError(f"n_out={n_out} not divisible by bn={bn}")
    n_kb = n_in // bk
    n_pb = n_out // bn
    nnz = nnz_for_sparsity(n_kb, sparsity)
    rng = np.random.default_rng(np.random.SeedSequence([seed, n_in, n_out, bk, bn]))
    idx = np.empty((n_pb, nnz), dtype=np.int32)
    for j in range(n_pb):
        idx[j] = np.sort(rng.permutation(n_kb)[:nnz]).astype(np.int32)
    return BlockSparsePlan(n_in=n_in, n_out=n_out, bk=bk, bn=bn, nnz=nnz,
                           indices=idx, seed=seed)


def plan_mask(plan: BlockSparsePlan, dtype=np.float32) -> np.ndarray:
    """Dense 0/1 mask of shape (n_in, n_out) described by the plan."""
    m = np.zeros((plan.n_kb, plan.n_pb), dtype=dtype)
    rows = plan.indices  # (n_pb, nnz)
    for j in range(plan.n_pb):
        m[rows[j], j] = 1.0
    # expand blocks
    m = np.repeat(np.repeat(m, plan.bk, axis=0), plan.bn, axis=1)
    return m


def pack_blocks(w: jnp.ndarray, plan: BlockSparsePlan) -> jnp.ndarray:
    """Gather the nonzero blocks of a dense (n_in, n_out) weight.

    Returns [n_pb, nnz, bk, bn]. Gradients flow through the gather, so this is
    also the training-time path (masked-weight training whose mask *is* the
    plan, i.e. straight-through on the kept blocks).
    """
    if w.shape != (plan.n_in, plan.n_out):
        raise ValueError(f"weight shape {w.shape} != plan ({plan.n_in},{plan.n_out})")
    wb = w.reshape(plan.n_kb, plan.bk, plan.n_pb, plan.bn)
    wb = wb.transpose(2, 0, 1, 3)  # (n_pb, n_kb, bk, bn)
    idx = jnp.asarray(plan.indices)  # (n_pb, nnz)
    return jnp.take_along_axis(wb, idx[:, :, None, None], axis=1)


def unpack_blocks(blocks: jnp.ndarray, plan: BlockSparsePlan) -> jnp.ndarray:
    """Scatter packed blocks back into a dense (n_in, n_out) weight (zeros elsewhere)."""
    n_pb, nnz, bk, bn = blocks.shape
    assert (n_pb, nnz, bk, bn) == (plan.n_pb, plan.nnz, plan.bk, plan.bn)
    dense = jnp.zeros((plan.n_pb, plan.n_kb, plan.bk, plan.bn), blocks.dtype)
    idx = jnp.asarray(plan.indices)
    dense = jax_scatter_along_axis1(dense, idx, blocks)
    return dense.transpose(1, 2, 0, 3).reshape(plan.n_in, plan.n_out)


def jax_scatter_along_axis1(dense, idx, blocks):
    """dense[(j, idx[j,t])] = blocks[j, t] — vectorized over j."""
    j = jnp.arange(dense.shape[0])[:, None]  # (n_pb, 1)
    return dense.at[j, idx].set(blocks)


def flat_block_table(plan: BlockSparsePlan) -> np.ndarray:
    """int32[n_pb * nnz] flattened index table (for scalar-prefetch kernels)."""
    return plan.indices.reshape(-1).astype(np.int32)


def sparsify_init(w: jnp.ndarray, plan: BlockSparsePlan) -> jnp.ndarray:
    """Apply the plan's mask to a dense init (zeros in pruned blocks)."""
    return w * jnp.asarray(plan_mask(plan, dtype=np.float32)).astype(w.dtype)
