"""Symmetric integer quantization with sub-byte bit-packing.

Kratos sweeps weight/input precision over {8, 4, 2, 1} bits and observes
super-linear area savings on the FPGA (multipliers are quadratic in bits).
On a TPU the datapath is fixed, so the wins are:

  * weight-memory bytes scale linearly with bits (int4/int2/int1 are packed
    into int8 lanes and unpacked in-kernel);
  * the MXU runs int8 x int8 at 2x the bf16 rate (394 vs 197 TOPS on v5e),
    credited in the roofline when both operands are quantized (w8a8).

Scheme: per-output-channel symmetric ("scale-only") quantization,
``w ~= q * scale`` with q in [-qmax, qmax]:

  bits=8 -> qmax=127, 1 value / int8
  bits=4 -> qmax=7,   2 values / int8 (low nibble first)
  bits=2 -> qmax=1,   4 values / int8 (ternary {-1,0,1})
  bits=1 -> q in {-1,+1} (sign), scale = mean(|w|) per channel (BinaryConnect)

Packing is along axis 0 (the reduction axis of ``y = x @ w``), so a kernel
unpacks contiguous k-runs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax.numpy as jnp

VALUES_PER_BYTE = {8: 1, 4: 2, 2: 4, 1: 8}
QMAX = {8: 127, 4: 7, 2: 1, 1: 1}
SUPPORTED_BITS = (8, 4, 2, 1)


@dataclasses.dataclass
class QuantizedTensor:
    """Packed integer data + per-channel scales for a 2-D weight."""

    data: jnp.ndarray    # int8[n_in // values_per_byte, n_out] (packed rows)
    scale: jnp.ndarray   # f32[n_out]
    bits: int
    shape: tuple         # original (n_in, n_out)

    @property
    def packed_bytes(self) -> int:
        return int(np.prod(self.data.shape)) + 4 * int(np.prod(self.scale.shape))

    def tree_flatten(self):
        return (self.data, self.scale), (self.bits, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, scale = children
        bits, shape = aux
        return cls(data=data, scale=scale, bits=bits, shape=shape)


import jax.tree_util
jax.tree_util.register_pytree_node(
    QuantizedTensor, QuantizedTensor.tree_flatten, QuantizedTensor.tree_unflatten)


def _check_bits(bits: int) -> None:
    if bits not in SUPPORTED_BITS:
        raise ValueError(f"bits must be one of {SUPPORTED_BITS}, got {bits}")


def _twn_threshold(w: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Ternary Weight Networks threshold: 0.7 * mean|w| per channel."""
    return 0.7 * jnp.mean(jnp.abs(w), axis=axis) + 1e-12


def compute_scale(w: jnp.ndarray, bits: int, axis: int = 0) -> jnp.ndarray:
    """Per-channel symmetric scale.

    8/4-bit: abs-max. 2-bit: TWN (Li & Liu 2016) — abs-max collapses a
    gaussian channel to {0, ±max} and measured WORSE than 1-bit; the TWN
    scale is the L2-optimal magnitude over the surviving (|w|>Δ) weights.
    1-bit: abs-mean (BinaryConnect, L1-optimal).
    """
    _check_bits(bits)
    if bits == 1:
        return jnp.mean(jnp.abs(w), axis=axis) + 1e-12
    if bits == 2:
        aw = jnp.abs(w)
        keep = aw > jnp.expand_dims(_twn_threshold(w, axis), axis)
        num = jnp.sum(jnp.where(keep, aw, 0.0), axis=axis)
        den = jnp.maximum(jnp.sum(keep, axis=axis), 1)
        return num / den + 1e-12
    return jnp.max(jnp.abs(w), axis=axis) / QMAX[bits] + 1e-12


def quantize_values(w: jnp.ndarray, scale: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Float weight -> int8 codes in [-qmax, qmax] (unpacked)."""
    _check_bits(bits)
    if bits == 1:
        return jnp.where(w >= 0, 1, -1).astype(jnp.int8)
    if bits == 2:
        thr = jnp.expand_dims(_twn_threshold(w, 0), 0)
        return jnp.where(jnp.abs(w) > thr,
                         jnp.sign(w), 0.0).astype(jnp.int8)
    q = jnp.round(w / scale)
    return jnp.clip(q, -QMAX[bits], QMAX[bits]).astype(jnp.int8)


def pack_codes(q: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack int8 codes along axis 0: `vpb` codes per output byte.

    Sub-byte fields are stored little-endian within the byte (value i of a
    group lands at bit-offset i*bits), in two's complement.
    """
    _check_bits(bits)
    vpb = VALUES_PER_BYTE[bits]
    if vpb == 1:
        return q
    n_in = q.shape[0]
    if n_in % vpb:
        raise ValueError(f"n_in={n_in} not divisible by values-per-byte={vpb}")
    mask = (1 << bits) - 1
    if bits == 1:
        # 1-bit codes are {-1,+1}: store the sign bit (1 = positive).
        qu = jnp.where(q > 0, 1, 0).astype(jnp.uint8)
    else:
        qu = q.astype(jnp.uint8) & mask                   # two's-complement field
    qu = qu.reshape(n_in // vpb, vpb, *q.shape[1:])
    acc = jnp.zeros(qu.shape[:1] + qu.shape[2:], jnp.uint8)
    for i in range(vpb):
        acc = acc | (qu[:, i] << jnp.uint8(i * bits))
    return acc.astype(jnp.int8)


def unpack_codes(packed: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Inverse of pack_codes: int8 packed -> int8 codes (sign-extended)."""
    _check_bits(bits)
    vpb = VALUES_PER_BYTE[bits]
    if vpb == 1:
        return packed
    pu = packed.astype(jnp.uint8)
    fields = []
    mask = (1 << bits) - 1
    sign_bit = 1 << (bits - 1)
    for i in range(vpb):
        f = (pu >> jnp.uint8(i * bits)) & mask
        if bits == 1:
            f = f.astype(jnp.int32) * 2 - 1            # sign bit -> {-1,+1}
        else:
            # sign-extend: (f ^ sign_bit) - sign_bit in int space
            f = (f.astype(jnp.int32) ^ sign_bit) - sign_bit
        fields.append(f.astype(jnp.int8))
    out = jnp.stack(fields, axis=1)  # (n_packed, vpb, ...)
    return out.reshape(packed.shape[0] * vpb, *packed.shape[1:])


def quantize(w: jnp.ndarray, bits: int) -> QuantizedTensor:
    """Quantize a (n_in, n_out) weight to a packed QuantizedTensor."""
    _check_bits(bits)
    scale = compute_scale(w, bits, axis=0)
    q = quantize_values(w, scale, bits)
    return QuantizedTensor(data=pack_codes(q, bits), scale=scale.astype(jnp.float32),
                           bits=bits, shape=tuple(w.shape))


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jnp.ndarray:
    codes = unpack_codes(qt.data, qt.bits)
    return (codes.astype(dtype) * qt.scale.astype(dtype)).astype(dtype)


def fake_quantize(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Quantize-dequantize roundtrip in float (QAT forward; STE backward is
    handled by callers via jax.lax.stop_gradient composition)."""
    scale = compute_scale(w, bits, axis=0)
    q = quantize_values(w, scale, bits).astype(w.dtype)
    return q * scale.astype(w.dtype)


def quantize_activations_int8(x: jnp.ndarray):
    """Dynamic per-row symmetric int8 activation quantization (for w8a8).

    x: (..., k) -> (codes int8 (..., k), scale f32 (..., 1))
    """
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)
