"""Kratos convolution kernels (conv1d / conv2d) on TPU via im2col onto the
Kratos GEMMs.

The paper's convolutions feed a fully-unrolled filter with an input-staging
network (BRAM for pixelwise, a shift-register network for row-parallel /
fully-unrolled). The TPU adaptation replaces the staging network with im2col
patch extraction (pure data movement, fused by XLA) and the unrolled filter
with a Kratos GEMM over the (Fw*Fh*Ic, Oc) weight — so filter sparsity and
precision get exactly the same treatment as GEMM weights.

The input unrolling factor becomes the number of output pixels contracted per
kernel invocation:
  pixelwise  -> m = 1 pixel  (grid sweeps output pixels)
  row        -> m = Ow       (one output row per step)
  full       -> m = Ow*Oh    (whole feature map in one shot)
For execution we always batch the full im2col (XLA fuses it); the unroll
factor drives the *throughput accounting* in the benchmark harness, same as
the paper's input/cycle column in Table I.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import kratos as kr


def im2col_1d(x: jnp.ndarray, fw: int) -> jnp.ndarray:
    """x: (B, Iw, Ic) -> patches (B, Ow, Fw*Ic); stride 1, no padding."""
    b, iw, ic = x.shape
    ow = iw - fw + 1
    cols = [x[:, i:i + ow, :] for i in range(fw)]
    return jnp.concatenate(cols, axis=-1).reshape(b, ow, fw * ic)


def im2col_2d(x: jnp.ndarray, fw: int, fh: int) -> jnp.ndarray:
    """x: (B, Iw, Ih, Ic) -> patches (B, Ow, Oh, Fw*Fh*Ic); stride 1, valid."""
    b, iw, ih, ic = x.shape
    ow, oh = iw - fw + 1, ih - fh + 1
    cols = []
    for di in range(fw):
        for dj in range(fh):
            cols.append(x[:, di:di + ow, dj:dj + oh, :])
    return jnp.concatenate(cols, axis=-1).reshape(b, ow, oh, fw * fh * ic)


def conv_weight_as_gemm(w: jnp.ndarray) -> jnp.ndarray:
    """(Fw, Fh, Ic, Oc) or (Fw, Ic, Oc) filter -> (Fw*[Fh*]Ic, Oc) GEMM weight.

    Axis order matches the im2col concat order (fw outer, fh inner, ic last).
    """
    return w.reshape(-1, w.shape[-1])


def conv1d(params: Dict, x: jnp.ndarray, spec: kr.KratosSpec = kr.DENSE,
           *, backend: str = "ref") -> jnp.ndarray:
    """params['w']: (Fw*Ic, Oc) GEMM-form filter; x: (B, Iw, Ic)."""
    wn, oc = params["w"].shape
    fw_ic = wn
    # infer Fw from stored aux
    fw = params.get("fw", None)
    if fw is None:
        raise ValueError("conv1d params must carry 'fw'")
    ic = fw_ic // fw
    patches = im2col_1d(x, fw)                       # (B, Ow, Fw*Ic)
    return kr.apply({"w": params["w"]}, patches, spec, backend=backend)


def conv2d(params: Dict, x: jnp.ndarray, spec: kr.KratosSpec = kr.DENSE,
           *, backend: str = "ref") -> jnp.ndarray:
    """params['w']: (Fw*Fh*Ic, Oc); params['fw'], params['fh']; x: (B, Iw, Ih, Ic)."""
    fw, fh = params["fw"], params["fh"]
    patches = im2col_2d(x, fw, fh)                   # (B, Ow, Oh, Fw*Fh*Ic)
    return kr.apply({"w": params["w"]}, patches, spec, backend=backend)


def conv1d_init(key, fw: int, ic: int, oc: int, spec: kr.KratosSpec = kr.DENSE,
                dtype=jnp.float32) -> Dict:
    p = kr.init(key, fw * ic, oc, spec, dtype)
    p["fw"] = fw
    return p


def conv2d_init(key, fw: int, fh: int, ic: int, oc: int,
                spec: kr.KratosSpec = kr.DENSE, dtype=jnp.float32) -> Dict:
    p = kr.init(key, fw * fh * ic, oc, spec, dtype)
    p["fw"], p["fh"] = fw, fh
    return p


def conv1d_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Ground-truth conv1d via lax.conv (w: (Fw, Ic, Oc))."""
    # lax conv wants NCW / OIW
    out = jax.lax.conv_general_dilated(
        x.transpose(0, 2, 1)[:, :, :],            # (B, Ic, Iw)
        w.transpose(2, 1, 0),                     # (Oc, Ic, Fw)
        window_strides=(1,), padding="VALID")
    return out.transpose(0, 2, 1)                 # (B, Ow, Oc)


def conv2d_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Ground-truth conv2d via lax.conv (w: (Fw, Fh, Ic, Oc); x: (B,Iw,Ih,Ic))."""
    out = jax.lax.conv_general_dilated(
        x.transpose(0, 3, 1, 2),                  # (B, Ic, Iw, Ih)
        w.transpose(3, 2, 0, 1),                  # (Oc, Ic, Fw, Fh)
        window_strides=(1, 1), padding="VALID")
    return out.transpose(0, 2, 3, 1)              # (B, Ow, Oh, Oc)
