"""KratosSpec / Kratos linear: the paper's contribution as a first-class,
composable JAX feature.

A `KratosSpec` attaches to any weight-stationary projection in any model
config and selects:

  * `impl='tree'`      — gathered block-sparse compute ('gemmt'): FLOPs and
                         weight traffic ∝ (1 - sparsity);
  * `impl='systolic'`  — dense compute on masked weights ('gemms'): zero
                         weights still cost full FLOPs (the paper's negative
                         control, and the dense fast path at sparsity 0);
  * `bits`             — weight precision in {8,4,2,1} (None = native bf16/f32);
                         training uses QAT fake-quant w/ straight-through
                         gradients, serving uses bit-packed kernels;
  * `act_bits=8`       — optional w8a8 (2x MXU rate on TPU);
  * `bk, bn`           — sparsity block granularity (the Table-III 'LUT size'
                         analogue, sweepable);
  * `unroll`           — 'pixelwise' | 'row' | 'full': the grid
                         parallelization degree (how much of the output is
                         produced per kernel invocation), Table I's input
                         unrolling factor.

Training params stay a dense float `w` (so optimizers/checkpoints are
oblivious); the plan is a pure function of (shape, spec) and is applied at
trace time. `pack()` converts trained params to packed serving buffers.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quantize as qz
from repro.core import sparsity as sp
from repro.kernels import ops
from repro.kernels import ref as kref

UNROLL_FACTORS = ("pixelwise", "row", "full")


@dataclasses.dataclass(frozen=True)
class KratosSpec:
    sparsity: float = 0.0
    bits: Optional[int] = None
    impl: str = "tree"                # 'tree' | 'systolic'
    unroll: str = "full"
    bk: int = 128
    bn: int = 128
    act_bits: Optional[int] = None    # 8 => w8a8 serving path
    seed: int = 0

    def __post_init__(self):
        if self.impl not in ("tree", "systolic"):
            raise ValueError(f"impl must be tree|systolic, got {self.impl}")
        if self.unroll not in UNROLL_FACTORS:
            raise ValueError(f"unroll must be one of {UNROLL_FACTORS}")
        if self.bits is not None and self.bits not in qz.SUPPORTED_BITS:
            raise ValueError(f"bits must be in {qz.SUPPORTED_BITS} or None")
        if self.act_bits not in (None, 8):
            raise ValueError("act_bits must be None or 8")

    @property
    def is_identity(self) -> bool:
        """True if this spec degenerates to a plain dense matmul."""
        return self.sparsity == 0.0 and self.bits is None and self.act_bits is None

    def with_(self, **kw) -> "KratosSpec":
        return dataclasses.replace(self, **kw)


DENSE = KratosSpec()


def spec_tag(sparsity: float, bits: Optional[int], act_bits: Optional[int],
             impl: str) -> str:
    """Artifact-tag fragment shared by serve.registry._spec_tag and
    serve.speculative.DraftSpec.tag — ONE formatter, so the registry's
    no-name-collision guarantee can't drift between the two."""
    b = "bf16" if bits is None else f"w{bits}"
    if act_bits:
        b += f"a{act_bits}"
    return f"s{sparsity:g}-{b}-{impl}"


@functools.lru_cache(maxsize=4096)
def _plan_cached(n_in: int, n_out: int, bk: int, bn: int,
                 sparsity_milli: int, seed: int) -> sp.BlockSparsePlan:
    return sp.make_plan(n_in, n_out, bk=bk, bn=bn,
                        sparsity=sparsity_milli / 1000.0, seed=seed)


def plan_for(n_in: int, n_out: int, spec: KratosSpec) -> Optional[sp.BlockSparsePlan]:
    """The (deterministic, cached) block plan for a given projection.

    Returns None (= dense) when the projection's shape doesn't divide the
    block grid: an arch-wide spec touches every GEMM in the model, and the
    odd-shaped ones (MLA rope stubs, SSM x_proj, routers) simply fall off
    the sparsity grid rather than failing the whole model — the paper's
    granularity lesson: the block geometry only pays where it fits.
    """
    if spec.sparsity == 0.0 or n_in % spec.bk or n_out % spec.bn:
        return None
    return _plan_cached(n_in, n_out, spec.bk, spec.bn,
                        int(round(spec.sparsity * 1000)), spec.seed)


# ---------------------------------------------------------------------------
# Init / training apply
# ---------------------------------------------------------------------------

def init(key, n_in: int, n_out: int, spec: KratosSpec = DENSE,
         dtype=jnp.float32, init_scale: Optional[float] = None) -> Dict[str, Any]:
    """Dense float master weight; pruned blocks start (and stay) zero."""
    scale = (n_in ** -0.5) if init_scale is None else init_scale
    w = jax.random.normal(key, (n_in, n_out), dtype) * jnp.asarray(scale, dtype)
    plan = plan_for(n_in, n_out, spec)
    if plan is not None:
        w = sp.sparsify_init(w, plan)
    return {"w": w}


def _ste_quant(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Fake-quant forward, straight-through backward."""
    return w + jax.lax.stop_gradient(qz.fake_quantize(w, bits) - w)


def apply(params: Dict[str, Any], x: jnp.ndarray, spec: KratosSpec = DENSE,
          *, backend: str = "ref") -> jnp.ndarray:
    """Training-time application: y = x @ kratos(w).

    x: (..., n_in) -> (..., n_out). The tree path gathers only live blocks,
    so jit/cost_analysis see (1 - sparsity) of the dense FLOPs; the systolic
    path multiplies a masked dense weight (full FLOPs) — faithful to Fig. 5.

    A `PackedLinear` leaf (serving trees built by serve.registry) dispatches
    to `apply_packed`, so the hot decode path runs on packed buffers while
    every model call site stays oblivious.
    """
    if isinstance(params, PackedLinear):
        # packed buffers are only meaningful under their pack-time spec —
        # the arch-wide `spec` argument may describe a DIFFERENT tier of
        # the same weights (serve.qos tier swaps)
        return apply_packed(params.buffers, x,
                            spec if params.spec is None else params.spec,
                            params.n_in, params.n_out, backend=backend)
    w = params["w"]
    n_in, n_out = w.shape
    lead = x.shape[:-1]
    xm = x.reshape(-1, n_in)
    if spec.bits is not None:
        w = _ste_quant(w, spec.bits)
    plan = plan_for(n_in, n_out, spec)
    if plan is None or spec.impl == "systolic":
        if plan is not None:  # systolic: mask, but pay dense compute
            w = w * jnp.asarray(sp.plan_mask(plan), w.dtype)
        y = ops.matmul(xm, w.astype(x.dtype), backend=backend) \
            if backend != "ref" else kref.dense_matmul_ref(xm, w.astype(x.dtype))
    else:
        blocks = sp.pack_blocks(w.astype(x.dtype), plan)
        # CO-DESIGN constraint (DESIGN.md §7): the packed blocks must keep
        # the weight's tensor-parallel output sharding — without this, the
        # pack reshape/gather loses it, every device computes ALL output
        # blocks, and the sparsity saving is eaten by replication. Requires
        # the block width bn to divide the TP shard width (n_out / |model|):
        # the sparsity granularity and the fabric's shard granularity are
        # coupled — the paper's LUT-size lesson reappearing as TP geometry.
        from repro.models import layers as L   # lazy: layers imports kratos
        blocks = L.shard(blocks, "out_blocks", None, None, None)
        if backend == "ref":
            y = kref.bsr_matmul_ref(xm, blocks, plan.indices)
        else:
            y = ops.bsr_matmul(xm, blocks, jnp.asarray(plan.indices),
                               backend=backend)
    return y.reshape(*lead, n_out)


# ---------------------------------------------------------------------------
# Serving: pack + apply_packed
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedLinear:
    """A projection frozen into packed serving buffers.

    A drop-in replacement for the training-time `{"w": ...}` leaf dict:
    `apply()` dispatches it to `apply_packed`, so a whole model's parameter
    tree can be re-pointed at packed buffers (serve.registry.pack_model_params)
    without touching any model code. The logical (n_in, n_out) shape rides in
    pytree aux-data — buffers alone can't recover it (the tree path drops
    pruned k-blocks, sub-byte codes fold `VALUES_PER_BYTE` rows per byte).

    Stacked scan-block projections keep a leading layer axis on every buffer;
    `lax.scan` slices the leaves per layer while (n_in, n_out) stay static.

    `spec` is the PACK-TIME spec (serving_spec-degraded): the buffers are
    only meaningful under the plan/bit-layout it describes, so `apply`
    consults it — not the arch-wide spec of the surrounding config — when
    dispatching a PackedLinear. This is what lets a QoS tier swap
    (serve.qos) re-point a live model at a tree packed under a DIFFERENT
    (sparsity, bits) point: the spec rides in pytree aux-data, so jit
    retraces against the right plan automatically.
    """

    buffers: Dict[str, Any]
    n_in: int
    n_out: int
    spec: Optional[KratosSpec] = None

    def tree_flatten(self):
        keys = tuple(sorted(self.buffers))
        return (tuple(self.buffers[k] for k in keys),
                (keys, self.n_in, self.n_out, self.spec))

    @classmethod
    def tree_unflatten(cls, aux, children):
        keys, n_in, n_out, spec = aux
        return cls(buffers=dict(zip(keys, children)), n_in=n_in, n_out=n_out,
                   spec=spec)

    @property
    def packed_bytes(self) -> int:
        total = 0
        for leaf in jax.tree_util.tree_leaves(self.buffers):
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        return total


jax.tree_util.register_pytree_node(
    PackedLinear, PackedLinear.tree_flatten, PackedLinear.tree_unflatten)


def pack_linear(params: Dict[str, Any], spec: KratosSpec) -> PackedLinear:
    """pack() a `{"w": (n_in, n_out)}` training leaf into a PackedLinear.

    A stacked `(n_layers, n_in, n_out)` weight (scan blocks) is packed per
    layer via vmap — the plan is shape-deterministic, so every layer shares
    it and the buffers stack cleanly.
    """
    w = params["w"]
    if w.ndim == 3:
        n_in, n_out = int(w.shape[1]), int(w.shape[2])
    elif w.ndim == 2:
        n_in, n_out = int(w.shape[0]), int(w.shape[1])
    else:
        raise ValueError(f"pack_linear expects a 2-D or stacked 3-D weight, "
                         f"got shape {w.shape}")
    spec = serving_spec(n_in, n_out, spec)
    if w.ndim == 3:
        buffers = jax.vmap(lambda wl: pack({"w": wl}, spec))(w)
    else:
        buffers = pack(params, spec)
    return PackedLinear(buffers=buffers, n_in=n_in, n_out=n_out, spec=spec)


def serving_spec(n_in: int, n_out: int, spec: KratosSpec) -> KratosSpec:
    """Degrade an arch-wide spec to what a given projection can pack.

    Sub-byte code packing folds `VALUES_PER_BYTE[bits]` reduction rows per
    byte; a projection (or sparse block) whose k-extent doesn't divide that
    keeps float weights. `apply_packed` dispatches on the buffer keys
    actually present, so pack- and apply-time decisions can't diverge.
    """
    if spec.bits is None:
        return spec
    vpb = qz.VALUES_PER_BYTE[spec.bits]
    tree = spec.impl == "tree" and plan_for(n_in, n_out, spec) is not None
    k_extent = spec.bk if tree else n_in
    if k_extent % vpb:
        spec = spec.with_(bits=None, act_bits=None)
    return spec


def pack(params: Dict[str, Any], spec: KratosSpec) -> Dict[str, Any]:
    """Convert trained dense params into packed inference buffers."""
    w = params["w"]
    n_in, n_out = w.shape
    plan = plan_for(n_in, n_out, spec)
    out: Dict[str, Any] = {}
    if plan is None or spec.impl == "systolic":
        if plan is not None:
            w = w * jnp.asarray(sp.plan_mask(plan), w.dtype)
        if spec.bits is None:
            out["w"] = w
        else:
            out["qt"] = qz.quantize(w, spec.bits)
        return out
    # tree path
    if spec.bits is None:
        out["blocks"] = sp.pack_blocks(w, plan)
    else:
        scale = qz.compute_scale(w, spec.bits)               # (n_out,)
        codes = qz.quantize_values(w, scale, spec.bits)      # int8 dense codes
        cblocks = sp.pack_blocks(codes, plan)                # (n_pb,nnz,bk,bn) i8
        n_pb, nnz, bk, bn = cblocks.shape
        vpb = qz.VALUES_PER_BYTE[spec.bits]
        packed = jax.vmap(lambda b: qz.pack_codes(b, spec.bits))(
            cblocks.reshape(n_pb * nnz, bk, bn))
        out["qblocks"] = packed.reshape(n_pb, nnz, bk // vpb, bn)
        out["qscale"] = jnp.asarray(scale, jnp.float32).reshape(n_pb, bn)
    return out


def apply_packed(packed: Dict[str, Any], x: jnp.ndarray, spec: KratosSpec,
                 n_in: int, n_out: int, *, backend: str = "ref") -> jnp.ndarray:
    """Inference-time application on packed buffers.

    Dispatch is keyed on WHICH buffers `pack()` produced (dense 'w',
    quantized 'qt', gathered 'blocks', quantized-gathered 'qblocks'), so a
    spec degraded at pack time (serving_spec) stays consistent here.
    """
    lead = x.shape[:-1]
    xm = x.reshape(-1, n_in)
    plan = None
    if "blocks" in packed or "qblocks" in packed:
        plan = plan_for(n_in, n_out, spec)
    if "w" in packed:
        y = kref.dense_matmul_ref(xm, packed["w"].astype(x.dtype)) \
            if backend == "ref" else ops.matmul(xm, packed["w"].astype(x.dtype),
                                                backend=backend)
    elif "qt" in packed:
        if spec.act_bits == 8 and packed["qt"].bits == 8:
            y = ops.quant_matmul_w8a8(xm, packed["qt"], backend=backend)
        else:
            y = ops.quant_matmul(xm, packed["qt"], backend=backend)
    elif "blocks" in packed:
        if backend == "ref":
            y = kref.bsr_matmul_ref(xm, packed["blocks"], plan.indices)
        else:
            y = ops.bsr_matmul(xm, packed["blocks"],
                               jnp.asarray(plan.indices), backend=backend)
    else:
        y = ops.bsr_quant_matmul(xm, packed["qblocks"], packed["qscale"],
                                 jnp.asarray(plan.indices), spec.bits,
                                 backend=backend)
    return y.reshape(*lead, n_out)


# ---------------------------------------------------------------------------
# Cost accounting (the 'area report' of the benchmark workflow)
# ---------------------------------------------------------------------------

def cost_report(n_in: int, n_out: int, spec: KratosSpec, m: int = 1,
                act_bytes: int = 2) -> Dict[str, float]:
    """Analytic effective cost of one application — the TPU restatement of
    the paper's ALM-utilization report.

    Returns effective MACs, weight bytes, and MXU-rate credit, relative and
    absolute. Dense bf16 reference: m*n_in*n_out MACs, 2 bytes/weight.
    """
    dense_macs = m * n_in * n_out
    plan = plan_for(n_in, n_out, spec)
    keep = 1.0 if plan is None else plan.dense_flops_fraction
    macs = dense_macs * (keep if spec.impl == "tree" else 1.0)
    wbits = 16 if spec.bits is None else spec.bits
    weight_bytes = n_in * n_out * wbits / 8.0
    if spec.impl == "tree":
        weight_bytes *= keep
    mxu_rate = 2.0 if (spec.act_bits == 8 and spec.bits == 8) else 1.0
    return {
        "dense_macs": float(dense_macs),
        "effective_macs": float(macs),
        "mac_fraction": float(macs / dense_macs),
        "weight_bytes": float(weight_bytes),
        "weight_bytes_fraction": float(weight_bytes / (2.0 * n_in * n_out)),
        "mxu_rate": mxu_rate,
        "equiv_compute_time_fraction": float(macs / dense_macs / mxu_rate),
    }
