"""Gradient compression with error feedback (the cross-pod bandwidth trick).

Two layers:

  * `ef_int8_compress` — numerics: per-tensor-block int8 quantization with
    an error-feedback accumulator (Karimireddy et al. style). Plugged into
    make_train_step(compress_fn=...); the EF state rides in the train state
    (and is checkpointed with it). Over DCN this cuts gradient bytes 4x
    vs f32 / 2x vs bf16 while EF keeps convergence (tested: a compressed
    run reaches the same loss band as an uncompressed one).

  * `cross_pod_psum_int8` — the wire pattern: a shard_map over the 'pod'
    axis that quantizes, psums the int32 codes, and dequantizes — i.e., the
    actual reduced-precision all-reduce a 1000-node deployment runs across
    its data-center interconnect. Exercised in tests on a fake multi-device
    mesh.
"""

from __future__ import annotations

import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quant_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_compress(grads, state):
    """Error-feedback int8 compression of a gradient pytree.

    state: pytree of f32 residuals matching grads (or None on first step —
    use `ef_init(params)`).
    """
    if state is None:
        state = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quant_int8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]))


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def cross_pod_psum_int8(x: jnp.ndarray, mesh, axis: str = "pod") -> jnp.ndarray:
    """All-reduce `x` over `axis` in int8-on-the-wire (int32 accumulate).

    x is assumed replicated over `axis` pre-reduction is wrong — each pod
    holds its own partial sum; we quantize the partial, reduce the integer
    codes, and dequantize with the max scale.
    """
    from jax.experimental.shard_map import shard_map

    n_axes = len(mesh.axis_names)
    spec = P(*([None] * x.ndim))

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=spec, out_specs=spec, check_rep=False)
    def reduce_fn(xx):
        q, scale = _quant_int8(xx)
        # shared scale: use the max scale across pods so codes are comparable
        smax = jax.lax.pmax(scale, axis)
        q = jnp.clip(jnp.round(xx / smax), -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis)
        return total.astype(jnp.float32) * smax

    return reduce_fn(x)
