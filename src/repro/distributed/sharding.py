"""Logical-axis sharding rules: DP / FSDP / TP / EP / SP over the production
mesh.

Mesh axes:
  single pod : (data=16, model=16)
  multi pod  : (pod=2, data=16, model=16) — 'pod' extends data parallelism by
               default (DCN-friendly: only gradient reduction crosses pods);
               the pipeline driver (distributed/pipeline.py) can claim it as
               a pipeline axis instead.

Parameter sharding is FSDP x TP: every 2-D projection is sharded over
('data' on its reduction-ish axis, 'model' on its parallel axis) so optimizer
state is fully sharded (ZeRO-3-equivalent); XLA inserts the per-layer
all-gathers. Rules are name-based over the parameter tree (the tree is ours,
so names are a stable contract). Stacked scan blocks get a leading None.

Activation rules (resolved by models.layers.shard):
  batch  -> ('pod', 'data')   heads/kv/ffn/vocab/expert -> 'model'
  seq    -> None (SP for saved residuals is a per-config option)
"""

from __future__ import annotations

import contextlib
import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import layers as L

# ---------------------------------------------------------------------------
# Logical activation axes
# ---------------------------------------------------------------------------

def activation_rules(mesh: Mesh, overrides: Optional[Dict] = None) -> Dict[str, Any]:
    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names) or None
    model = "model" if "model" in names else None
    rules = {
        "batch": batch,
        "seq": None,
        "seq_res": model,     # SP: residual-stream / remat-carry seq sharding
        "heads": model,
        "kv_heads": model,
        "ffn": model,
        "vocab": model,
        "expert": model,
        # 2D-TP serving mode (§Perf): d_model contraction dim over 'data' so
        # weights stay resident (no per-step FSDP re-gather); off by default.
        "dm_in": None,
        # Kratos packed-block output axis (core.kratos.apply tree path)
        "out_blocks": model,
    }
    if overrides:
        rules.update(overrides)
    return rules


def _resolver_for(mesh: Mesh, overrides: Optional[Dict] = None):
    rules = activation_rules(mesh, overrides)

    def resolve(x, logical_axes):
        spec = []
        used = set()                      # a mesh axis may appear only once
        for ax, dim in zip(logical_axes, x.shape):
            mesh_ax = rules.get(ax) if ax is not None else None
            if mesh_ax is None:
                spec.append(None)
                continue
            axes = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
            shards = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % shards or any(a in used for a in axes):
                spec.append(None)
            else:
                used.update(axes)
                spec.append(mesh_ax)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))

    return resolve


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rule_overrides: Optional[Dict] = None):
    """Install the mesh + logical resolver for model-internal constraints."""
    prev = L._LOGICAL_RESOLVER
    L.set_logical_resolver(_resolver_for(mesh, rule_overrides))
    try:
        with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
                else contextlib.nullcontext():
            with mesh:
                yield mesh
    finally:
        L.set_logical_resolver(prev)


# ---------------------------------------------------------------------------
# Parameter partition specs (name-based)
# ---------------------------------------------------------------------------

# parent-key names of column-parallel projections: out axis -> 'model'
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "wq_a", "wq_b", "wkv_a", "wkv_b",
        "in_proj", "head"}
# row-parallel: in axis -> 'model'
_ROW = {"wo", "w_down", "out_proj", "x_proj"}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
        else:
            names.append(str(k))
    return tuple(names)


def _spec_for(names: Tuple[str, ...], ndim: int, stacked: bool,
              fsdp_axis: Optional[str] = "data") -> P:
    base_ndim = ndim - (1 if stacked else 0)
    lead = (None,) if stacked else ()
    fa = fsdp_axis

    def mk(*axes):
        return P(*(lead + axes))

    nm = set(names)
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""

    # --- MoE stacked expert weights (E, d, f) / (E, f, d) ---
    if base_ndim == 3 and parent not in ("dt_proj",) and \
            leaf in ("w_gate", "w_up", "w_down"):
        if leaf == "w_down":
            return mk("model", None, fa)
        return mk("model", fa, None)
    if leaf == "emb":
        return mk("model", fa)
    if parent == "router" and leaf == "w":
        return mk(fa, "model")
    if parent == "dt_proj":
        return mk(None, "model") if base_ndim == 2 else mk("model")
    if leaf == "conv_w":
        return mk(None, "model")
    if leaf in ("conv_b", "D"):
        return mk("model")
    if leaf == "A_log":
        return mk("model", None)
    if leaf == "w" and parent in _COL:
        return mk(fa, "model")
    if leaf == "w" and parent in _ROW:
        return mk("model", fa)
    if leaf in ("scale", "bias"):                    # norms: shard last dim
        return mk(*([None] * (base_ndim - 1) + ["model"]))
    if leaf == "w" and base_ndim == 2:               # default 2-D projection
        return mk(fa, "model")
    return mk(*([None] * base_ndim))


def _sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the dim —
    uneven shardings are disallowed for jit arguments (vocab 73448 on a
    16-way axis, kv=20 heads on model=16, ...)."""
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        shards = int(np.prod([mesh.shape[a] for a in axes]))
        out.append(entry if shape[i] % shards == 0 else None)
    return P(*out)


def param_pspecs(params, mesh: Optional[Mesh] = None) -> Any:
    """PartitionSpec pytree matching a model parameter tree."""
    def one(path, leaf):
        names = _path_names(path)
        stacked = any(n in ("blocks", "enc_blocks") for n in names)
        spec = _spec_for(names, np.ndim(leaf), stacked)
        if mesh is not None and hasattr(leaf, "shape"):
            spec = _sanitize_spec(spec, leaf.shape, mesh)
        return spec

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(mesh: Mesh, params) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), param_pspecs(params, mesh))


# ---------------------------------------------------------------------------
# Cache partition specs
# ---------------------------------------------------------------------------

def cache_pspecs(caches, mesh: Mesh, batch_size: int, *,
                 slab: bool = False) -> Any:
    """Shard KV caches: batch over ('pod','data') when divisible, else the
    cache *sequence* axis over 'data' (the long_500k single-request cell).
    The 'model' axis lands on kv-heads when divisible, otherwise on the
    cache sequence axis (e.g. kv=8 heads on a model=16 mesh — padding-free
    vs a 2x-waste uneven head sharding). d_inner (SSM) over 'model'.

    slab=True: the tree is a serving KV slab (serve.cache_pool) whose
    leading axis is `n_slots`, not a lock-step batch. Two rules change:
      * non-divisible slot counts REPLICATE instead of falling back to the
        long-context seq-over-'data' layout — every slot row is scattered at
        its own dynamic offset each micro-step, so a seq-sharded slab turns
        each per-slot write into a cross-device exchange;
      * leaves the name rules don't recognize still shard their leading
        slot axis like batch (previously they fell through to fully
        replicated as an "unknown dim").
    """
    names = mesh.axis_names
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    model_n = mesh.shape["model"] if "model" in names else 1
    if dp_axes and batch_size % dp == 0:
        b_ax, seq_ax = dp_axes, None
    elif "data" in names and batch_size % mesh.shape["data"] == 0:
        b_ax, seq_ax = "data", None
    elif slab:
        b_ax, seq_ax = None, None
    else:
        b_ax, seq_ax = None, "data"

    def one(path, leaf):
        spec = _raw(path, leaf)
        if hasattr(leaf, "shape"):      # drop non-divisible entries per leaf
            spec = _sanitize_spec(spec, leaf.shape, mesh)
        return spec

    def _raw(path, leaf):
        names_ = _path_names(path)
        stacked = any(n == "blocks" for n in names_)
        lead = (None,) if stacked else ()
        leafname = names_[-1]
        nd = np.ndim(leaf) - len(lead)
        shape = leaf.shape[len(lead):] if hasattr(leaf, "shape") else ()
        if leafname in ("k", "v"):          # (B, KV, S, dh)
            kv_n, s_n = shape[1], shape[2]
            if kv_n % model_n == 0:
                return P(*(lead + (b_ax, "model", seq_ax, None)))
            # kv heads don't divide 'model'. Sharding the cache SEQ over
            # 'model' forces a per-layer cache all-gather at decode (1.5 GiB
            # x 96 layers on nemotron = the entire collective term), so:
            #   small cache -> batch-only (fully local attention);
            #   oversized cache (nemotron 2.5 TB) -> batch over 'model' (+
            #   'pod') and seq over 'data': heads stay whole, attention runs
            #   partial-softmax over 'data' with KB-scale reductions instead
            #   of GiB-scale gathers.
            if b_ax is not None:
                leaf_bytes = float(np.prod(leaf.shape)) * leaf.dtype.itemsize
                dp = int(np.prod([mesh.shape[a] for a in
                                  (b_ax if isinstance(b_ax, tuple)
                                   else (b_ax,))]))
                if leaf_bytes / dp <= (4 << 30):
                    return P(*(lead + (b_ax, None, seq_ax, None)))
                m_batch = tuple(a for a in ("pod", "model") if a in names)
                mb_n = int(np.prod([mesh.shape[a] for a in m_batch]))
                if not slab and shape[0] % mb_n == 0 \
                        and s_n % mesh.shape["data"] == 0:
                    return P(*(lead + (m_batch, None, "data", None)))
                return P(*(lead + (b_ax, None, seq_ax, None)))
            if slab:                        # never seq-shard a slot slab
                return P(*(lead + (b_ax, None, None, None)))
            m_seq = "model" if seq_ax is None else (seq_ax, "model")
            if s_n % (model_n * (1 if seq_ax is None else mesh.shape["data"])) == 0:
                return P(*(lead + (b_ax, None, m_seq, None)))
            return P(*(lead + (b_ax, None, seq_ax, None)))
        # MLA latent caches: keep seq over 'model' — the per-layer latent
        # gather is tiny (~19 MB: no head axis), while batch-only sharding
        # makes the per-head expansion run unsharded (24 GiB on minicpm3;
        # measured regression, reverted — §Perf H1 post-mortem). Serving
        # slabs (per-slot dynamic scatters) keep the seq axis whole.
        if leafname == "c_kv":              # (B, S, r) — latent, no head axis
            m_seq = None if slab else \
                ("model" if seq_ax is None else (seq_ax, "model"))
            return P(*(lead + (b_ax, m_seq, None)))
        if leafname == "k_rope":            # (B, 1, S, dr)
            m_seq = None if slab else \
                ("model" if seq_ax is None else (seq_ax, "model"))
            return P(*(lead + (b_ax, None, m_seq, None)))
        if leafname == "ssm":               # (B, di, st)
            return P(*(lead + (b_ax, "model", None)))
        if leafname == "conv":              # (B, K-1, di)
            return P(*(lead + (b_ax, None, "model")))
        if slab and nd >= 1 and shape and shape[0] == batch_size:
            # unknown slab leaf: the leading slot axis still shards like
            # batch; everything after it stays replicated.
            return P(*(lead + (b_ax,) + (None,) * (nd - 1)))
        return P(*(lead + (None,) * nd))

    return jax.tree_util.tree_map_with_path(one, caches)


def page_pspecs(caches, layout, mesh: Mesh, n_pages: int) -> list:
    """PartitionSpecs for a PAGE-MAJOR KV store (serve.paging).

    `caches` is the slab template (`T.make_caches(cfg, n_slots, cache_len)`
    shapes), `layout` a `serve.paging.PageLayout` over it. Paged leaves
    shard their PAGE axis — which sits exactly where the slab's slot axis
    sat (PageLayout.store_shapes) — the way the slab shards its slot axis
    (`batch_pspec(mesh, n_pages)` — replicated fallback when the page
    count doesn't divide the dp axes, so the donated paged decode step
    always has a legal placement); the rest of a paged leaf's spec is the
    slab rule (`cache_pspecs(slab=True)`) with the sequence entry cleared
    — kv-heads stay on 'model', the page-interior position axis is never
    sharded (every page is written at dynamic offsets, and the Pallas
    kernel's index map addresses whole pages). Resident leaves keep their
    slab spec unchanged. Returns a flat list aligned with the store's leaf
    order.
    """
    slab_specs = jax.tree_util.tree_leaves(
        cache_pspecs(caches, mesh, layout.n_slots, slab=True),
        is_leaf=lambda x: isinstance(x, P))
    page_entry = batch_pspec(mesh, n_pages)
    page_ent = tuple(page_entry)[0] if len(tuple(page_entry)) else None
    out = []
    store_shapes = layout.store_shapes(n_pages)
    for spec, slab_shape, store_shape, ls in zip(
            slab_specs, layout.slab_shapes, store_shapes, layout.specs):
        if not ls.paged:
            out.append(spec)
            continue
        ent = list(spec) + [None] * (len(slab_shape) - len(spec))
        ent[ls.batch_axis] = page_ent      # page axis replaces slot axis
        ent[-2] = None                     # page interior: never sharded
        out.append(_sanitize_spec(P(*ent), store_shape, mesh))
    return out


def batch_pspec(mesh: Mesh, batch_size: int) -> P:
    """Batch-axis spec: the combined ('pod','data') tuple when the batch
    divides the FULL mesh (so downstream reshapes can re-split it over any
    axis subset), a plain 'data' entry when it only divides the data axis,
    replicated otherwise. Multi-dp-axis meshes keep the tuple whenever the
    dp product divides — 'pod' x 'data' must shard together or not at all.

    The serving slab's per-slot vectors (steps.decode_state_pspecs) use
    this with batch_size = n_slots: the slot axis of the (K, B) token block
    and every lifecycle vector shards exactly like the slab's leading slot
    axis, and the replicated fallback keeps non-divisible slot counts legal
    as donated jit arguments (never an uneven sharding error)."""
    names = mesh.axis_names
    dp_axes = tuple(a for a in ("pod", "data") if a in names)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    total = int(np.prod([mesh.shape[a] for a in names]))
    if dp_axes and batch_size % dp == 0:
        if batch_size % total == 0 or len(dp_axes) > 1 \
                or "data" not in names:
            return P(dp_axes)
        return P("data")
    if "data" in names and batch_size % mesh.shape["data"] == 0:
        return P("data")
    return P(None)
