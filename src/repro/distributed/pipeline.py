"""GPipe-style pipeline parallelism over the cross-pod ('pod') mesh axis.

At 1000+ nodes the pod-to-pod interconnect (DCN) is ~10x slower than
intra-pod ICI, so the cheapest thing to send across it is *activations of a
layer boundary*, not gradients of every parameter. This module implements the
schedule with `shard_map` + `jax.lax.ppermute`:

  * the layer stack is split into `n_stages` contiguous stages, stage s's
    parameters living only on pod s (cutting per-pod parameter + optimizer
    memory by n_stages);
  * a step runs `n_micro` microbatches; at tick t, stage s processes
    microbatch (t - s) and ppermutes its activation to stage s+1 — the
    classic pipeline diagonal with (n_stages - 1) bubble ticks;
  * backward runs the mirrored schedule (handled by jax.grad through the
    ppermutes — reverse-mode of a ppermute is the opposite ppermute).

This is exercised as an alternative to pod-as-extra-DP on a stacked-MLP tower
(tests/test_pipeline.py validates exact equivalence with the sequential
model); wiring it under the full transformer is a config flag surfaced in
EXPERIMENTS.md §Perf as a cross-pod optimization.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(
    stage_params: Any,          # pytree, leaves stacked (n_stages, ...)
    x: jnp.ndarray,             # (n_micro, micro_batch, d) microbatched input
    stage_fn: Callable,         # stage_fn(params_slice, h) -> h
    mesh: Mesh,
    axis: str = "pod",
) -> jnp.ndarray:
    """Run x through n_stages pipeline stages laid out along `axis`.

    Returns (n_micro, micro_batch, d) outputs (as produced by the last stage,
    gathered back to all pods for convenience).
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro >= n_stages, "need >= n_stages microbatches to fill the pipe"

    pspec_params = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    in_specs = (pspec_params, P(None))          # params sharded, x replicated
    out_specs = P(None)

    @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    def run(params, xs):
        # params: leaves (1, ...) — this pod's stage; xs: (n_micro, mb, d)
        params = jax.tree_util.tree_map(lambda p: p[0], params)
        stage_id = jax.lax.axis_index(axis)
        total_ticks = n_micro + n_stages - 1
        mb, d = xs.shape[1], xs.shape[2]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (when valid); others use buf
            inject = jnp.where(t < n_micro, t, 0)
            h_in = jnp.where(stage_id == 0, xs[inject], buf)
            h_out = stage_fn(params, h_in)
            # last stage records its result at position (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            write = (stage_id == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            # shift activations one stage forward
            buf = jax.lax.ppermute(
                h_out, axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outs), None

        buf0 = jnp.zeros((mb, d), xs.dtype)
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(total_ticks))
        # broadcast the last stage's outputs to every pod
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    return run(stage_params, x)


def pipeline_reference(stage_params, x, stage_fn):
    """Sequential oracle: run all stages in order on each microbatch."""
    n_stages = jax.tree_util.tree_leaves(stage_params)[0].shape[0]

    def apply_all(h):
        for s in range(n_stages):
            ps = jax.tree_util.tree_map(lambda p: p[s], stage_params)
            h = stage_fn(ps, h)
        return h

    return jax.vmap(apply_all)(x)
