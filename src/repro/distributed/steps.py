"""train_step / serve_step builders (pjit-ready, microbatched, remat-aware).

The steps are pure functions over (state, batch) suitable for jax.jit with
in/out shardings from distributed.sharding. Gradient accumulation splits the
per-step batch into `grad_accum` microbatches consumed by a lax.scan — the
standard trick that bounds saved-activation memory for the 340B config.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.distributed.sharding import batch_pspec
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim import adamw as O


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: T.ModelConfig, backend: str = "ref"):
    def loss_fn(params, batch):
        enc_out = None
        if cfg.enc_dec:
            enc_out = T.encode(params, batch["frames"], cfg, backend=backend)
        logits, aux, _ = T.forward(
            params, batch["tokens"], cfg, backend=backend,
            img_embeds=batch.get("img_embeds"), enc_out=enc_out)
        if cfg.n_img_tokens:
            logits = logits[:, cfg.n_img_tokens:]
        loss = T.lm_loss(logits, batch["labels"])
        return loss + aux.astype(jnp.float32), loss
    return loss_fn


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def make_train_step(cfg: T.ModelConfig, opt_cfg: O.OptimizerConfig,
                    *, grad_accum: int = 1, backend: str = "ref",
                    compress_fn: Optional[Callable] = None,
                    accum_dtype=jnp.float32):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {'params', 'opt', 'step'}; batch = {'tokens','labels',...}.
    compress_fn: optional gradient-compression hook
    (distributed.compression) applied to accumulated grads; it receives and
    returns (grads, compression_state) and state rides in `state['comp']`.
    accum_dtype: gradient-accumulation buffer dtype. f32 default; bf16
    halves the largest training temp (the grad tree) — used by the 340B
    dry-run policy, a standard memory/precision trade at that scale.
    """
    loss_fn = make_loss_fn(cfg, backend)
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    accum_dtype = jnp.dtype(accum_dtype)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (total, loss), grads = vg(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % grad_accum == 0, (b, grad_accum)
                mb = b // grad_accum
                # reshape (mb, ga) THEN swap: a split dim's sharding lands on
                # the major-most factor, and it must stay on the batch-row dim
                # (axis 1 after the swap), not on the microbatch index — else
                # every scan iteration gathers the full global batch.
                x = x.reshape(mb, grad_accum, *x.shape[1:]).swapaxes(0, 1)
                return L.shard(x, None, "batch", *([None] * (x.ndim - 2)))

            micro = jax.tree_util.tree_map(split, batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def body(carry, mb):
                g_acc, l_acc = carry
                (tot, l), g = vg(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(accum_dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum

        new_comp = state.get("comp")
        if compress_fn is not None:
            grads, new_comp = compress_fn(grads, state.get("comp"))

        new_p, new_opt, gn = O.adamw_update(grads, state["opt"], params,
                                            opt_cfg)
        new_state = {"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_comp is not None:
            new_state["comp"] = new_comp
        metrics = {"loss": loss, "grad_norm": gn,
                   "lr": O.warmup_cosine(opt_cfg, new_opt["count"])}
        return new_state, metrics

    return train_step


def init_train_state(key, cfg: T.ModelConfig, opt_cfg: O.OptimizerConfig):
    params = T.init(key, cfg)
    return {"params": params, "opt": O.adamw_init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: T.ModelConfig, backend: str = "ref",
                      last_only: bool = True, *,
                      cache_len: Optional[int] = None,
                      cache_dtype=jnp.float32):
    """prefill(params, batch[, caches]) -> (next_token_logits, caches).

    last_only=False returns the full (B, S, vocab) logits — the serve engine
    right-pads prompts into compile-shape buckets and reads the logits column
    at the true prompt end, so it needs every position.

    cache_len: when set, the step allocates its own batch-1 cache tree of
    this length INSIDE the compiled function (zeros materialize directly on
    device) and the `caches` operand disappears — the donation-friendly form
    the serving engine uses: no host-side template is copied in per
    admission, and the returned cache buffers can be donated straight into
    the slab write (CachePool.write_slot).
    """
    # remat exists to trade recompute for backward-pass memory; inference has
    # no backward pass, and the checkpoint wrapper's conditional-update
    # plumbing forced whole-cache-stack f32 convert/select churn per layer
    # (~3.5 TB/step on nemotron decode). Always off for serving.
    cfg = dataclasses.replace(cfg, remat=False)

    def body(params, batch, caches):
        enc_out = None
        if cfg.enc_dec:
            enc_out = T.encode(params, batch["frames"], cfg, backend=backend)
        logits, _, caches = T.forward(
            params, batch["tokens"], cfg, backend=backend, caches=caches,
            img_embeds=batch.get("img_embeds"), enc_out=enc_out,
            last_only=last_only)
        return logits, caches

    if cache_len is None:
        def prefill(params, batch, caches):
            return body(params, batch, caches)
    else:
        def prefill(params, batch):
            return body(params, batch,
                        T.make_caches(cfg, 1, cache_len, cache_dtype))
    return prefill


def make_decode_step(cfg: T.ModelConfig, backend: str = "ref", *,
                     n_steps: Optional[int] = None,
                     pages_meta: Optional[Dict[str, int]] = None,
                     ledger=None):
    """Compiled slab decode. Two forms:

    n_steps=None (legacy, lock-step launch path):
        decode(params, caches, token, index) -> (logits, caches)
    token: (B, 1) int32; index: scalar int32 count of tokens already cached
    (lock-step batch), or an int32 (B,) vector of PER-SLOT counts — the
    continuous-batching slab decode, where each cache row advances on its
    own clock (serve.engine). One compiled step serves both regimes; the
    vector form gathers/scatters per-slot cache offsets (models.attention).

    n_steps=K (device-resident loop, serve.engine):
        decode(params, caches, state) -> (tok_block, caches, state)
    runs K micro-steps in ONE dispatch via `lax.scan`, with sampling fused on
    device (T.sample_tokens — per-slot temperature, threaded jax.random key)
    and per-slot EOS / length masking, so only the (K, B) int32 `tok_block`
    ever crosses to the host. `state` is the device-resident per-slot loop
    state (see `make_decode_state`); callers donate both `caches` and
    `state`, so the KV slab updates in place instead of being copied per
    token. The rng key is split once per MICRO-step (not per dispatch),
    which makes sampled sequences identical for any K grouping of the same
    steps. Slots that finish mid-block (EOS or length) freeze their token /
    index / rng-free state; the host catches up from the synced block and
    frees them retroactively.

    pages_meta={'size': page_size, 'len': cache_len} (n_steps form only)
    builds the NATIVE PAGED variant: the returned fn takes an extra
    `page_table` operand after `caches` —
        decode(params, caches, page_table, state)
            -> (tok_block, caches, page_table, state)
    — and every forward threads pages={'table', 'size', 'len'} so the
    attention layers read/write the page-major cache leaves through the
    table (models.attention). The table is loop-invariant inside the
    dispatch (admission updates it between dispatches) and passes through
    so it stays aliased to its donated buffer.

    ledger=serve.ledger.LedgerConfig (n_steps form only) appends the
    ineffectual-work ledger as a trailing DONATED operand and return: every
    micro-step's forward runs with a LedgerProbe, the per-layer probe
    matrix accumulates in the scan carry, and the cumulative
    (n_layers, width) f32 buffer comes back for the engine to drain inside
    the dispatch's one existing host sync —
        decode(params, caches, state, ledger) ->
            (tok_block, caches, state, ledger)
    (paged form: the ledger operand stays last, after `state`).
    """
    cfg = dataclasses.replace(cfg, remat=False)   # see make_prefill_step

    if n_steps is None:
        if pages_meta is not None:
            raise ValueError("pages_meta requires the n_steps form")
        if ledger is not None:
            raise ValueError("ledger requires the n_steps form")
        def decode(params, caches, token, index):
            logits, _, caches = T.forward(
                params, token, cfg, backend=backend, caches=caches,
                index=index)
            return logits, caches
        return decode

    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")

    if ledger is not None:
        from repro.serve.ledger import LedgerProbe   # lazy: no serve<->models cycle

    def decode(params, caches, state, page_table=None, ledger_in=None):
        pages = None if page_table is None else dict(pages_meta,
                                                     table=page_table)

        def micro(carry, _):
            if ledger is not None:
                caches, st, led = carry
                probe = LedgerProbe(ledger)
            else:
                caches, st = carry
                probe = None
            out = T.forward(
                params, st["tokens"][:, None], cfg, backend=backend,
                caches=caches, index=st["index"], pages=pages, probe=probe)
            if ledger is not None:
                logits, _, caches, mat = out
                led = led + mat
            else:
                logits, _, caches = out
            key, sub = jax.random.split(st["key"])
            tok = T.sample_tokens(logits[:, -1], sub, st["temperature"])
            active = st["active"]
            tok = jnp.where(active, tok, st["tokens"])
            remaining = jnp.where(active, st["remaining"] - 1,
                                  st["remaining"])
            hit_eos = active & (st["eos"] >= 0) & (tok == st["eos"])
            st = {
                "tokens": tok,
                "index": jnp.where(active, st["index"] + 1, st["index"]),
                "key": key,
                "temperature": st["temperature"],
                "eos": st["eos"],
                "remaining": remaining,
                "active": active & (remaining > 0) & ~hit_eos,
                "spec_limit": st["spec_limit"],
            }
            carry = (caches, st, led) if ledger is not None else (caches, st)
            return carry, tok

        if ledger is not None:
            (caches, state, led), tok_block = jax.lax.scan(
                micro, (caches, state, ledger_in), None, length=n_steps)
            return tok_block, caches, state, led
        (caches, state), tok_block = jax.lax.scan(
            micro, (caches, state), None, length=n_steps)
        return tok_block, caches, state

    if pages_meta is not None:
        if ledger is not None:
            def paged_decode(params, caches, page_table, state, ledger_in):
                tok_block, caches, state, led = decode(
                    params, caches, state, page_table, ledger_in)
                return tok_block, caches, page_table, state, led
            return paged_decode

        def paged_decode(params, caches, page_table, state):
            tok_block, caches, state = decode(params, caches, state,
                                              page_table)
            return tok_block, caches, page_table, state
        return paged_decode
    if ledger is not None:
        def ledger_decode(params, caches, state, ledger_in):
            return decode(params, caches, state, None, ledger_in)
        return ledger_decode
    return decode


def make_decode_state(n_slots: int, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Device-resident per-slot loop state for the fused decode step.

    tokens/index: the (B,) feedback loop that never leaves the device;
    temperature/eos/remaining/active: per-slot sampling + lifecycle vectors,
    written only at admission; key: the threaded jax.random key; spec_limit:
    the per-request speculation cap (max draft tokens acceptable per
    dispatch, `Request.speculate`) — 0 opts the slot out of drafting, in
    which case the verify step degenerates to exactly one plain target
    micro-step for that slot.
    """
    return {
        "tokens": jnp.zeros((n_slots,), jnp.int32),
        "index": jnp.zeros((n_slots,), jnp.int32),
        "key": jax.random.PRNGKey(seed),
        "temperature": jnp.zeros((n_slots,), jnp.float32),
        "eos": jnp.full((n_slots,), -1, jnp.int32),
        "remaining": jnp.zeros((n_slots,), jnp.int32),
        "active": jnp.zeros((n_slots,), bool),
        "spec_limit": jnp.zeros((n_slots,), jnp.int32),
    }


def decode_state_pspecs(mesh, n_slots: int) -> Dict[str, PartitionSpec]:
    """PartitionSpec tree matching `make_decode_state(n_slots)`.

    Every per-slot lifecycle vector is (n_slots,) and shards exactly like
    the slab's leading slot axis (sharding.batch_pspec — replicated when
    n_slots doesn't divide the dp axes, so the donated decode step always
    has a legal placement); the threaded rng key is replicated — each
    micro-step's split must agree on every device."""
    slot_spec = batch_pspec(mesh, n_slots)
    spec = {k: slot_spec for k in ("tokens", "index", "temperature", "eos",
                                   "remaining", "active", "spec_limit")}
    spec["key"] = PartitionSpec(None)
    return spec


def install_slot(state: Dict[str, jnp.ndarray], slot, token, index,
                 temperature, eos, remaining,
                 spec_limit=0) -> Dict[str, jnp.ndarray]:
    """Write one admitted request's row into the device decode state.

    Pure (jit with donated `state` by the engine): slot may be a traced
    int32. eos < 0 means no EOS; remaining <= 0 installs an inactive row
    (request finished at prefill). spec_limit: per-request speculation cap
    (0 = no drafting for this slot; ignored by the plain decode step)."""
    return {
        "tokens": state["tokens"].at[slot].set(token),
        "index": state["index"].at[slot].set(index),
        "key": state["key"],
        "temperature": state["temperature"].at[slot].set(temperature),
        "eos": state["eos"].at[slot].set(eos),
        "remaining": state["remaining"].at[slot].set(remaining),
        "active": state["active"].at[slot].set(remaining > 0),
        "spec_limit": state["spec_limit"].at[slot].set(spec_limit),
    }


# ---------------------------------------------------------------------------
# Paged decode: page-table indirection around the same fused steps
# ---------------------------------------------------------------------------

def make_paged_decode_step(cfg: T.ModelConfig, backend: str = "ref", *,
                           n_steps: int, layout, native: bool = True,
                           ledger=None):
    """Paged form of the device-resident loop (serve.paging):

        decode(params, store, page_table, state)
            -> (tok_block, store, page_table, state)

    `store` is the page-major KV store (flat leaf list), `page_table` the
    (n_slots, pages_per_slot) int32 table — BOTH donated device state, like
    the slab and the loop state today.

    native=True (default): NO gather/scatter. The store leaves pass
    straight into the forward as the cache tree (`layout.as_tree` — the
    page axis sits where the slot axis sat, so the treedef is unchanged)
    and the attention layers read/write them THROUGH the table
    (models.attention paged branches / kernels.ops.paged_attention): new-
    token writes are in-place page-indexed scatters that preserve the
    donated store's buffer aliasing, and no per-dispatch slab view ever
    materializes.

    native=False keeps the legacy wrap for A/B tests: gather each slot's
    pages into exactly the slab layout, run the unchanged fused decode,
    scatter the touched pages back (traces serve.paging.GATHER_EVENTS).
    Both forms are greedy token-identical to the slab — the native ref
    read is the same sliced-view attention program the gather produced.

    The table passes through unchanged (admission and slot release update
    it between dispatches); returning it keeps it aliased to its donated
    buffer so it stays device-resident.

    ledger=LedgerConfig appends the donated ineffectual-work ledger as a
    trailing operand/return on either form (see make_decode_step)."""
    if native:
        meta = {"size": layout.page_size, "len": layout.cache_len}
        inner = make_decode_step(cfg, backend, n_steps=n_steps,
                                 pages_meta=meta, ledger=ledger)

        if ledger is not None:
            def decode(params, store, page_table, state, ledger_in):
                caches = layout.as_tree(store)
                tok_block, caches, page_table, state, led = inner(
                    params, caches, page_table, state, ledger_in)
                return (tok_block, layout.from_tree(caches), page_table,
                        state, led)
            return decode

        def decode(params, store, page_table, state):
            caches = layout.as_tree(store)
            tok_block, caches, page_table, state = inner(
                params, caches, page_table, state)
            return tok_block, layout.from_tree(caches), page_table, state

        return decode

    inner = make_decode_step(cfg, backend, n_steps=n_steps, ledger=ledger)

    if ledger is not None:
        def decode(params, store, page_table, state, ledger_in):
            caches = layout.gather(store, page_table)
            tok_block, caches, state, led = inner(params, caches, state,
                                                  ledger_in)
            return (tok_block, layout.scatter(store, page_table, caches),
                    page_table, state, led)
        return decode

    def decode(params, store, page_table, state):
        caches = layout.gather(store, page_table)
        tok_block, caches, state = inner(params, caches, state)
        return (tok_block, layout.scatter(store, page_table, caches),
                page_table, state)

    return decode


def make_paged_speculative_decode_step(cfg: T.ModelConfig,
                                       draft_cfg: T.ModelConfig,
                                       backend: str = "ref", *,
                                       n_draft: int, layout,
                                       native: bool = True,
                                       ledger=None):
    """Paged form of the fused propose-then-verify cycle:

        spec_decode(params, draft_params, store, page_table, draft_caches,
                    state) -> (commit, n_commit, n_accept, store,
                               page_table, draft_caches, state)

    Only the TARGET store is paged (it is the memory that scales with
    prompts; the draft slab is small by construction and keeps the plain
    slab layout + slot clocks — its forwards never see `pages`). Rollback
    semantics survive paging for free: a rejected suffix is a per-slot
    index rewind that never frees a page, and the speculative write
    headroom lands in the slot's PRIVATE tail pages (prefix sharing only
    ever publishes pages with complete final KV), so a rolled-back write
    can never have touched a shared page.

    native=True: the verify forwards consume the page table directly (same
    contract as make_paged_decode_step) — the K+1-token block write is one
    page-indexed scatter per leaf. native=False keeps the legacy
    gather/scatter wrap for A/B tests.

    ledger=LedgerConfig appends the donated ineffectual-work ledger as a
    trailing operand/return on either form (see
    make_speculative_decode_step)."""
    if native:
        meta = {"size": layout.page_size, "len": layout.cache_len}
        inner = make_speculative_decode_step(cfg, draft_cfg, backend,
                                             n_draft=n_draft,
                                             pages_meta=meta, ledger=ledger)

        if ledger is not None:
            def spec_decode(params, draft_params, store, page_table,
                            draft_caches, state, ledger_in):
                caches = layout.as_tree(store)
                (commit, m, acc, caches, page_table, draft_caches, state,
                 led) = inner(params, draft_params, caches, page_table,
                              draft_caches, state, ledger_in)
                return (commit, m, acc, layout.from_tree(caches),
                        page_table, draft_caches, state, led)
            return spec_decode

        def spec_decode(params, draft_params, store, page_table,
                        draft_caches, state):
            caches = layout.as_tree(store)
            commit, m, acc, caches, page_table, draft_caches, state = inner(
                params, draft_params, caches, page_table, draft_caches,
                state)
            return (commit, m, acc, layout.from_tree(caches), page_table,
                    draft_caches, state)

        return spec_decode

    inner = make_speculative_decode_step(cfg, draft_cfg, backend,
                                         n_draft=n_draft, ledger=ledger)

    if ledger is not None:
        def spec_decode(params, draft_params, store, page_table,
                        draft_caches, state, ledger_in):
            caches = layout.gather(store, page_table)
            commit, m, acc, caches, draft_caches, state, led = inner(
                params, draft_params, caches, draft_caches, state,
                ledger_in)
            return (commit, m, acc,
                    layout.scatter(store, page_table, caches), page_table,
                    draft_caches, state, led)
        return spec_decode

    def spec_decode(params, draft_params, store, page_table, draft_caches,
                    state):
        caches = layout.gather(store, page_table)
        commit, m, acc, caches, draft_caches, state = inner(
            params, draft_params, caches, draft_caches, state)
        return (commit, m, acc, layout.scatter(store, page_table, caches),
                page_table, draft_caches, state)

    return spec_decode


def make_suffix_prefill_step(cfg: T.ModelConfig, backend: str = "ref", *,
                             layout, ledger=None):
    """Prefill ONLY the unmatched suffix of a prompt whose prefix pages are
    already resident (serve.paging prefix reuse):

        prefill(params, batch, store, page_table, slot, index)
            -> ((1, S, vocab) suffix logits, store)

    Gathers the slot's batch-1 view (the shared prefix pages supply
    positions < index), runs the suffix through the DECODE-form forward —
    the same s>1 contiguous block write the speculative verify uses
    (attention._decode_cache_write / mla_apply with a scalar `index`), so
    suffix tokens attend to the cached prefix under the standard validity
    masks — and scatters the view back: fresh suffix pages receive the new
    KV, shared prefix pages receive back the identical values they
    supplied. `index` is the matched prefix length (traced). The engine
    right-pads suffixes into pow2 buckets exactly like full prefills
    (compile O(log max_len) suffix shapes, not one per length — real
    traffic produces arbitrary suffix lengths); the FULL (1, S, vocab)
    logits come back so the caller reads the true suffix-end column, and
    the padded tail's block writes land past the shared region in the
    slot's private pages, masked by the validity clocks until decode
    overwrites them — the same contract as the slab's padded prefill
    tail.

    ledger=serve.ledger.LedgerConfig appends the donated ineffectual-work
    ledger as a trailing operand/return:
        prefill(params, batch, store, page_table, slot, index, ledger)
            -> (logits, store, ledger)."""
    cfg = dataclasses.replace(cfg, remat=False)   # see make_prefill_step

    if ledger is not None:
        from repro.serve.ledger import LedgerProbe   # lazy: no serve<->models cycle

        def prefill(params, batch, store, page_table, slot, index,
                    ledger_in):
            row = jax.lax.dynamic_index_in_dim(page_table, slot, axis=0,
                                               keepdims=False)
            caches = layout.gather_one(store, row, slot)
            probe = LedgerProbe(ledger)
            logits, _, caches, mat = T.forward(
                params, batch["tokens"], cfg, backend=backend,
                caches=caches, index=index, probe=probe)
            return (logits, layout.scatter_one(store, row, slot, caches),
                    ledger_in + mat)
        return prefill

    def prefill(params, batch, store, page_table, slot, index):
        row = jax.lax.dynamic_index_in_dim(page_table, slot, axis=0,
                                           keepdims=False)
        caches = layout.gather_one(store, row, slot)
        logits, _, caches = T.forward(
            params, batch["tokens"], cfg, backend=backend, caches=caches,
            index=index)
        return logits, layout.scatter_one(store, row, slot, caches)

    return prefill


def page_table_pspec(mesh, n_slots: int) -> PartitionSpec:
    """(n_slots, pages_per_slot) table: slot axis sharded like the slab's
    slot axis / the decode-state vectors, page entries replicated."""
    return PartitionSpec(*(tuple(batch_pspec(mesh, n_slots)) + (None,)))


# ---------------------------------------------------------------------------
# Speculative decode: fused propose-then-verify (serve.speculative)
# ---------------------------------------------------------------------------

def recurrent_cache_paths(caches) -> list:
    """Flat-leaf indices of NON-POSITIONAL cache leaves + their batch axis.

    Attention/MLA caches are positional — every write lands at a per-slot
    sequence offset, so rolling back rejected speculative tokens is a free
    index rewind (stale positions are masked, then overwritten). SSM leaves
    ('conv' tail, 'ssm' state — models.ssm.make_mamba_cache) are RECURRENT:
    the state after K tokens cannot be rewound, so the speculative step
    snapshots them per micro-step and gathers the per-slot accepted state
    back (see make_speculative_decode_step). Returns [(flat_index,
    batch_axis)] in jax tree-flatten order; batch_axis is 1 for
    layer-stacked 'blocks' leaves, 0 for 'prelude' leaves.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(caches)
    out = []
    for i, (path, _leaf) in enumerate(flat):
        names = [str(k.key) for k in path if hasattr(k, "key")]
        if "conv" in names or "ssm" in names:
            out.append((i, 1 if names and names[0] == "blocks" else 0))
    return out


def _snapshot(caches, paths):
    leaves = jax.tree_util.tree_flatten(caches)[0]
    return [leaves[i] for i, _ in paths]


def _gather_step(stacked, g, batch_axis):
    """stacked: (T, *leaf); g: (B,) int32 step index per batch row. Exact
    one-hot select along T (where + sum — one term per element, no fp
    blending) with the batch axis at `batch_axis` of the leaf."""
    t = stacked.shape[0]
    steps = jnp.arange(t).reshape((t,) + (1,) * (stacked.ndim - 1))
    gshape = [1] * stacked.ndim
    gshape[batch_axis + 1] = g.shape[0]
    mask = steps == g.reshape(gshape)
    return jnp.where(mask, stacked, 0).sum(axis=0).astype(stacked.dtype)


def _restore(caches, paths, init_leaves, step_stacks, g):
    """Replace recurrent leaves with the per-slot state at step g[b]
    (g = 0 selects the pre-dispatch state prepended from init_leaves)."""
    leaves, treedef = jax.tree_util.tree_flatten(caches)
    for (i, bax), init, snap in zip(paths, init_leaves, step_stacks):
        stacked = jnp.concatenate([init[None], snap], axis=0)
        leaves[i] = _gather_step(stacked, g, bax)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def make_speculative_decode_step(cfg: T.ModelConfig,
                                 draft_cfg: T.ModelConfig,
                                 backend: str = "ref", *, n_draft: int,
                                 pages_meta: Optional[Dict[str, int]] = None,
                                 ledger=None):
    """Fused propose-then-verify decode (serve.speculative):

        spec_decode(params, draft_params, caches, draft_caches, state)
            -> (commit (B, K+1), n_commit (B,), n_accept (B,),
                caches, draft_caches, state)

    pages_meta={'size', 'len'} builds the NATIVE PAGED variant (an extra
    `page_table` operand after `caches`, threaded into the TARGET forwards
    as the `pages` operand and passed through the return — see
    make_decode_step; the draft keeps its slab).

    ledger=serve.ledger.LedgerConfig appends the donated ineffectual-work
    ledger as a trailing operand/return (see make_decode_step). Only the
    TARGET verify forwards are probed — the draft's cost is accounted
    analytically (registry.draft_cost_fraction), so probing it would
    double-count work the roofline already attributes.

    ONE dispatch per cycle, everything on device:

      1. DRAFT: the cheap artifact runs K+1 micro-steps under one lax.scan
         (K proposals d_1..d_K, plus one trailing feed of d_K so the draft
         slab/state covers the fully-accepted case), sampling with the
         per-slot temperature vector and the threaded rng key.
      2. VERIFY: the target scores the whole block [t0, d_1..d_K] — ONE
         batched (B, K+1) forward with per-slot index clocks for
         positional-cache archs; for recurrent archs (SSM/hybrid, whose
         single-step recurrence cannot consume a block) a K+1-step scan of
         single-token forwards that snapshots the recurrent leaves per step.
      3. ACCEPT/REJECT per slot: greedy rows accept the longest prefix where
         the draft token equals the target argmax; temperature>0 rows use
         the standard rejection-sampling test (u < p/q) and, at the first
         rejection, sample the correction from the residual (p - q)+ — the
         committed stream is distributed exactly as the target. The run is
         clamped by the per-slot `spec_limit` (a 0 row degenerates to one
         plain target step). One bonus token from the target's column L
         always commits, so every cycle advances every live slot by
         1..K+1 tokens.
      4. ROLLBACK: rejected suffixes cost a per-slot index rewind —
         positional cache writes past the new clock are masked and later
         overwritten in place (the engine pads the slab by K positions so
         the deepest speculative write stays in bounds); recurrent leaves
         gather the per-slot state at the accepted boundary from the
         step-stacked snapshots (frozen slots gather their pre-dispatch
         state). EOS / length budgets truncate the commit on device, same
         contract as the plain multi-step loop.

    Greedy speculative output is token-identical to plain greedy decode:
    every committed draft token equals the target argmax on the committed
    prefix, and the bonus IS the target argmax — the accepted stream is the
    target's greedy stream by induction, for any draft and any K.
    """
    cfg = dataclasses.replace(cfg, remat=False)        # see make_prefill_step
    draft_cfg = dataclasses.replace(draft_cfg, remat=False)
    if n_draft < 1:
        raise ValueError(f"n_draft must be >= 1, got {n_draft}")
    k = n_draft
    recurrent = bool(cfg.is_ssm or cfg.attn_period)

    if ledger is not None:
        from repro.serve.ledger import LedgerProbe   # lazy: no serve<->models cycle

    def spec_decode(params, draft_params, caches, draft_caches, state,
                    page_table=None, ledger_in=None):
        pages = None if page_table is None else dict(pages_meta,
                                                     table=page_table)
        b = state["tokens"].shape[0]
        active = state["active"]
        idx0 = state["index"]
        temp = state["temperature"]

        # ---- 1. draft proposes (K+1 fused micro-steps) --------------------
        d_paths = recurrent_cache_paths(draft_caches)
        d_init = _snapshot(draft_caches, d_paths)

        def draft_micro(carry, _):
            dcaches, tok, idx, key = carry
            logits, _, dcaches = T.forward(
                draft_params, tok[:, None], draft_cfg, backend=backend,
                caches=dcaches, index=idx)
            key, sub = jax.random.split(key)
            nxt = T.sample_tokens(logits[:, -1], sub, temp)
            nxt = jnp.where(active, nxt, tok)
            idx = jnp.where(active, idx + 1, idx)
            return ((dcaches, nxt, idx, key),
                    (nxt, logits[:, -1], _snapshot(dcaches, d_paths)))

        (draft_caches, _, _, key), (props, dlogits, d_snaps) = jax.lax.scan(
            draft_micro, (draft_caches, state["tokens"], idx0, state["key"]),
            None, length=k + 1)
        d_block = props[:k].T                           # (B, K): d_1..d_K
        dlog = dlogits[:k].transpose(1, 0, 2)           # (B, K, vocab)

        # ---- 2. target verifies the block --------------------------------
        tok_in = jnp.concatenate([state["tokens"][:, None], d_block], axis=1)
        t_paths = recurrent_cache_paths(caches)
        t_init = _snapshot(caches, t_paths)
        led = ledger_in
        if not recurrent:
            if ledger is not None:
                probe = LedgerProbe(ledger)
                logits, _, caches, mat = T.forward(
                    params, tok_in, cfg, backend=backend, caches=caches,
                    index=idx0, pages=pages, probe=probe)
                led = led + mat
            else:
                logits, _, caches = T.forward(
                    params, tok_in, cfg, backend=backend, caches=caches,
                    index=idx0, pages=pages)
            z = logits                                  # (B, K+1, vocab)
            t_snaps = []
        else:
            def verify_micro(carry, xs):
                vcaches, vled = carry
                tok_j, j = xs
                idx_j = jnp.where(active, idx0 + j, idx0)
                if ledger is not None:
                    probe = LedgerProbe(ledger)
                    lg, _, vcaches, mat = T.forward(
                        params, tok_j[:, None], cfg, backend=backend,
                        caches=vcaches, index=idx_j, pages=pages,
                        probe=probe)
                    vled = vled + mat
                else:
                    lg, _, vcaches = T.forward(
                        params, tok_j[:, None], cfg, backend=backend,
                        caches=vcaches, index=idx_j, pages=pages)
                return ((vcaches, vled),
                        (lg[:, -1], _snapshot(vcaches, t_paths)))

            (caches, led), (zs, t_snaps) = jax.lax.scan(
                verify_micro, (caches, led),
                (tok_in.T, jnp.arange(k + 1, dtype=jnp.int32)))
            z = zs.transpose(1, 0, 2)

        # ---- 3. per-slot accept/reject ------------------------------------
        greedy = temp <= 0.0
        tgt_next = jnp.argmax(z, axis=-1).astype(jnp.int32)   # (B, K+1)
        match = d_block == tgt_next[:, :k]
        key, k_acc, k_bonus = jax.random.split(key, 3)
        safe_t = jnp.maximum(temp, 1e-6)[:, None, None]
        logp = jax.nn.log_softmax(z[:, :k].astype(jnp.float32) / safe_t, -1)
        logq = jax.nn.log_softmax(dlog.astype(jnp.float32) / safe_t, -1)
        p_d = jnp.take_along_axis(logp, d_block[..., None], axis=-1)[..., 0]
        q_d = jnp.take_along_axis(logq, d_block[..., None], axis=-1)[..., 0]
        u = jax.random.uniform(k_acc, (b, k), minval=1e-20)
        accept = jnp.where(greedy[:, None], match, jnp.log(u) < p_d - q_d)
        run = jnp.cumprod(accept.astype(jnp.int32), axis=1)
        l_run = jnp.sum(run, axis=1)
        l_acc = jnp.minimum(l_run, state["spec_limit"])       # per-slot cap

        # bonus token from the target's column L: greedy argmax; sampled
        # rows draw from the residual (p - q)+ at a TRUE rejection column,
        # from p itself when the run was clamped or fully accepted.
        z_l = jax.vmap(lambda zb, lb: zb[lb])(z, l_acc)       # (B, vocab)
        bonus_g = jnp.argmax(z_l, axis=-1).astype(jnp.int32)
        dlog_pad = jnp.concatenate([dlog, jnp.zeros_like(dlog[:, :1])], 1)
        q_l = jax.vmap(lambda qb, lb: qb[lb])(dlog_pad, l_acc)
        logp_l = jax.nn.log_softmax(z_l.astype(jnp.float32)
                                    / safe_t[:, 0], -1)
        logq_l = jax.nn.log_softmax(q_l.astype(jnp.float32)
                                    / safe_t[:, 0], -1)
        resid = jnp.log(jnp.clip(jnp.exp(logp_l) - jnp.exp(logq_l),
                                 1e-30, None))
        # the correction conditions on "an ELIGIBLE draft token was
        # rejected": at a spec_limit-clamped column the draft token could
        # never commit regardless of the accept test, so the bonus must be
        # a plain draw from p (a capped/opted-out slot is exactly one plain
        # target step), not the residual.
        use_resid = (l_acc == l_run) & (l_run < k) \
            & (l_run < state["spec_limit"])
        t_logits = jnp.where(use_resid[:, None], resid, logp_l)
        gum = jax.random.gumbel(k_bonus, z_l.shape, jnp.float32)
        bonus_t = jnp.argmax(t_logits + gum, axis=-1).astype(jnp.int32)
        bonus = jnp.where(greedy, bonus_g, bonus_t)

        # ---- 4. commit + on-device lifecycle ------------------------------
        ar = jnp.arange(k + 1)[None]
        d_pad = jnp.concatenate([d_block, jnp.zeros((b, 1), jnp.int32)], 1)
        commit = jnp.where(ar < l_acc[:, None], d_pad, 0)
        commit = jnp.where(ar == l_acc[:, None], bonus[:, None], commit)
        m_full = l_acc + 1
        is_eos = ((state["eos"][:, None] >= 0)
                  & (commit == state["eos"][:, None])
                  & (ar < m_full[:, None]))
        first_eos = jnp.min(jnp.where(is_eos, ar, k + 1), axis=1)
        m = jnp.minimum(m_full,
                        jnp.minimum(first_eos + 1, state["remaining"]))
        m = jnp.where(active, m, 0)
        hit_eos = (first_eos + 1) <= m
        remaining = state["remaining"] - m
        last = jax.vmap(lambda cb, mb: cb[jnp.maximum(mb - 1, 0)])(commit, m)
        new_state = {
            "tokens": jnp.where(active, last, state["tokens"]),
            "index": idx0 + m,               # the rollback: rewind the clock
            "key": key,
            "temperature": temp,
            "eos": state["eos"],
            "remaining": remaining,
            "active": active & (remaining > 0) & ~hit_eos,
            "spec_limit": state["spec_limit"],
        }
        # accepted = draft tokens actually COMMITTED: EOS/budget truncation
        # takes the first m commit columns, of which min(l_acc, m) are
        # drafts (the bonus commits only when m == l_acc + 1) — accepted-
        # then-truncated positions are rewound, so they must not count.
        n_accept = jnp.where(active, jnp.minimum(l_acc, m), 0)

        # ---- 5. recurrent-state rollback ----------------------------------
        if recurrent:
            # committed state = after consuming [t0, d_1..d_L] = micro-step
            # L+1 (0 = the prepended pre-dispatch state, which frozen slots
            # keep). Identical step indexing for draft and target: both fed
            # the same committed prefix.
            g = jnp.where(active, l_acc + 1, 0)
            caches = _restore(caches, t_paths, t_init, t_snaps, g)
            draft_caches = _restore(draft_caches, d_paths, d_init, d_snaps, g)

        if ledger is not None:
            return commit, m, n_accept, caches, draft_caches, new_state, led
        return commit, m, n_accept, caches, draft_caches, new_state

    if pages_meta is not None:
        if ledger is not None:
            def paged_spec_decode(params, draft_params, caches, page_table,
                                  draft_caches, state, ledger_in):
                (commit, m, acc, caches, draft_caches, state,
                 led) = spec_decode(params, draft_params, caches,
                                    draft_caches, state, page_table,
                                    ledger_in)
                return (commit, m, acc, caches, page_table, draft_caches,
                        state, led)
            return paged_spec_decode

        def paged_spec_decode(params, draft_params, caches, page_table,
                              draft_caches, state):
            commit, m, acc, caches, draft_caches, state = spec_decode(
                params, draft_params, caches, draft_caches, state,
                page_table)
            return (commit, m, acc, caches, page_table, draft_caches,
                    state)
        return paged_spec_decode
    if ledger is not None:
        def ledger_spec_decode(params, draft_params, caches, draft_caches,
                               state, ledger_in):
            return spec_decode(params, draft_params, caches, draft_caches,
                               state, None, ledger_in)
        return ledger_spec_decode
    return spec_decode
