"""train_step / serve_step builders (pjit-ready, microbatched, remat-aware).

The steps are pure functions over (state, batch) suitable for jax.jit with
in/out shardings from distributed.sharding. Gradient accumulation splits the
per-step batch into `grad_accum` microbatches consumed by a lax.scan — the
standard trick that bounds saved-activation memory for the 340B config.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.distributed.sharding import batch_pspec
from repro.models import layers as L
from repro.models import transformer as T
from repro.optim import adamw as O


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: T.ModelConfig, backend: str = "ref"):
    def loss_fn(params, batch):
        enc_out = None
        if cfg.enc_dec:
            enc_out = T.encode(params, batch["frames"], cfg, backend=backend)
        logits, aux, _ = T.forward(
            params, batch["tokens"], cfg, backend=backend,
            img_embeds=batch.get("img_embeds"), enc_out=enc_out)
        if cfg.n_img_tokens:
            logits = logits[:, cfg.n_img_tokens:]
        loss = T.lm_loss(logits, batch["labels"])
        return loss + aux.astype(jnp.float32), loss
    return loss_fn


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def make_train_step(cfg: T.ModelConfig, opt_cfg: O.OptimizerConfig,
                    *, grad_accum: int = 1, backend: str = "ref",
                    compress_fn: Optional[Callable] = None,
                    accum_dtype=jnp.float32):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {'params', 'opt', 'step'}; batch = {'tokens','labels',...}.
    compress_fn: optional gradient-compression hook
    (distributed.compression) applied to accumulated grads; it receives and
    returns (grads, compression_state) and state rides in `state['comp']`.
    accum_dtype: gradient-accumulation buffer dtype. f32 default; bf16
    halves the largest training temp (the grad tree) — used by the 340B
    dry-run policy, a standard memory/precision trade at that scale.
    """
    loss_fn = make_loss_fn(cfg, backend)
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    accum_dtype = jnp.dtype(accum_dtype)

    def train_step(state, batch):
        params = state["params"]
        if grad_accum == 1:
            (total, loss), grads = vg(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % grad_accum == 0, (b, grad_accum)
                mb = b // grad_accum
                # reshape (mb, ga) THEN swap: a split dim's sharding lands on
                # the major-most factor, and it must stay on the batch-row dim
                # (axis 1 after the swap), not on the microbatch index — else
                # every scan iteration gathers the full global batch.
                x = x.reshape(mb, grad_accum, *x.shape[1:]).swapaxes(0, 1)
                return L.shard(x, None, "batch", *([None] * (x.ndim - 2)))

            micro = jax.tree_util.tree_map(split, batch)
            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def body(carry, mb):
                g_acc, l_acc = carry
                (tot, l), g = vg(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(accum_dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            (grads, loss_sum), _ = jax.lax.scan(
                body, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum

        new_comp = state.get("comp")
        if compress_fn is not None:
            grads, new_comp = compress_fn(grads, state.get("comp"))

        new_p, new_opt, gn = O.adamw_update(grads, state["opt"], params,
                                            opt_cfg)
        new_state = {"params": new_p, "opt": new_opt,
                     "step": state["step"] + 1}
        if new_comp is not None:
            new_state["comp"] = new_comp
        metrics = {"loss": loss, "grad_norm": gn,
                   "lr": O.warmup_cosine(opt_cfg, new_opt["count"])}
        return new_state, metrics

    return train_step


def init_train_state(key, cfg: T.ModelConfig, opt_cfg: O.OptimizerConfig):
    params = T.init(key, cfg)
    return {"params": params, "opt": O.adamw_init(params, opt_cfg),
            "step": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: T.ModelConfig, backend: str = "ref",
                      last_only: bool = True, *,
                      cache_len: Optional[int] = None,
                      cache_dtype=jnp.float32):
    """prefill(params, batch[, caches]) -> (next_token_logits, caches).

    last_only=False returns the full (B, S, vocab) logits — the serve engine
    right-pads prompts into compile-shape buckets and reads the logits column
    at the true prompt end, so it needs every position.

    cache_len: when set, the step allocates its own batch-1 cache tree of
    this length INSIDE the compiled function (zeros materialize directly on
    device) and the `caches` operand disappears — the donation-friendly form
    the serving engine uses: no host-side template is copied in per
    admission, and the returned cache buffers can be donated straight into
    the slab write (CachePool.write_slot).
    """
    # remat exists to trade recompute for backward-pass memory; inference has
    # no backward pass, and the checkpoint wrapper's conditional-update
    # plumbing forced whole-cache-stack f32 convert/select churn per layer
    # (~3.5 TB/step on nemotron decode). Always off for serving.
    cfg = dataclasses.replace(cfg, remat=False)

    def body(params, batch, caches):
        enc_out = None
        if cfg.enc_dec:
            enc_out = T.encode(params, batch["frames"], cfg, backend=backend)
        logits, _, caches = T.forward(
            params, batch["tokens"], cfg, backend=backend, caches=caches,
            img_embeds=batch.get("img_embeds"), enc_out=enc_out,
            last_only=last_only)
        return logits, caches

    if cache_len is None:
        def prefill(params, batch, caches):
            return body(params, batch, caches)
    else:
        def prefill(params, batch):
            return body(params, batch,
                        T.make_caches(cfg, 1, cache_len, cache_dtype))
    return prefill


def make_decode_step(cfg: T.ModelConfig, backend: str = "ref", *,
                     n_steps: Optional[int] = None):
    """Compiled slab decode. Two forms:

    n_steps=None (legacy, lock-step launch path):
        decode(params, caches, token, index) -> (logits, caches)
    token: (B, 1) int32; index: scalar int32 count of tokens already cached
    (lock-step batch), or an int32 (B,) vector of PER-SLOT counts — the
    continuous-batching slab decode, where each cache row advances on its
    own clock (serve.engine). One compiled step serves both regimes; the
    vector form gathers/scatters per-slot cache offsets (models.attention).

    n_steps=K (device-resident loop, serve.engine):
        decode(params, caches, state) -> (tok_block, caches, state)
    runs K micro-steps in ONE dispatch via `lax.scan`, with sampling fused on
    device (T.sample_tokens — per-slot temperature, threaded jax.random key)
    and per-slot EOS / length masking, so only the (K, B) int32 `tok_block`
    ever crosses to the host. `state` is the device-resident per-slot loop
    state (see `make_decode_state`); callers donate both `caches` and
    `state`, so the KV slab updates in place instead of being copied per
    token. The rng key is split once per MICRO-step (not per dispatch),
    which makes sampled sequences identical for any K grouping of the same
    steps. Slots that finish mid-block (EOS or length) freeze their token /
    index / rng-free state; the host catches up from the synced block and
    frees them retroactively.
    """
    cfg = dataclasses.replace(cfg, remat=False)   # see make_prefill_step

    if n_steps is None:
        def decode(params, caches, token, index):
            logits, _, caches = T.forward(
                params, token, cfg, backend=backend, caches=caches,
                index=index)
            return logits, caches
        return decode

    if n_steps < 1:
        raise ValueError(f"n_steps must be >= 1, got {n_steps}")

    def decode(params, caches, state):
        def micro(carry, _):
            caches, st = carry
            logits, _, caches = T.forward(
                params, st["tokens"][:, None], cfg, backend=backend,
                caches=caches, index=st["index"])
            key, sub = jax.random.split(st["key"])
            tok = T.sample_tokens(logits[:, -1], sub, st["temperature"])
            active = st["active"]
            tok = jnp.where(active, tok, st["tokens"])
            remaining = jnp.where(active, st["remaining"] - 1,
                                  st["remaining"])
            hit_eos = active & (st["eos"] >= 0) & (tok == st["eos"])
            st = {
                "tokens": tok,
                "index": jnp.where(active, st["index"] + 1, st["index"]),
                "key": key,
                "temperature": st["temperature"],
                "eos": st["eos"],
                "remaining": remaining,
                "active": active & (remaining > 0) & ~hit_eos,
            }
            return (caches, st), tok

        (caches, state), tok_block = jax.lax.scan(
            micro, (caches, state), None, length=n_steps)
        return tok_block, caches, state

    return decode


def make_decode_state(n_slots: int, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Device-resident per-slot loop state for the fused decode step.

    tokens/index: the (B,) feedback loop that never leaves the device;
    temperature/eos/remaining/active: per-slot sampling + lifecycle vectors,
    written only at admission; key: the threaded jax.random key.
    """
    return {
        "tokens": jnp.zeros((n_slots,), jnp.int32),
        "index": jnp.zeros((n_slots,), jnp.int32),
        "key": jax.random.PRNGKey(seed),
        "temperature": jnp.zeros((n_slots,), jnp.float32),
        "eos": jnp.full((n_slots,), -1, jnp.int32),
        "remaining": jnp.zeros((n_slots,), jnp.int32),
        "active": jnp.zeros((n_slots,), bool),
    }


def decode_state_pspecs(mesh, n_slots: int) -> Dict[str, PartitionSpec]:
    """PartitionSpec tree matching `make_decode_state(n_slots)`.

    Every per-slot lifecycle vector is (n_slots,) and shards exactly like
    the slab's leading slot axis (sharding.batch_pspec — replicated when
    n_slots doesn't divide the dp axes, so the donated decode step always
    has a legal placement); the threaded rng key is replicated — each
    micro-step's split must agree on every device."""
    slot_spec = batch_pspec(mesh, n_slots)
    spec = {k: slot_spec for k in ("tokens", "index", "temperature", "eos",
                                   "remaining", "active")}
    spec["key"] = PartitionSpec(None)
    return spec


def install_slot(state: Dict[str, jnp.ndarray], slot, token, index,
                 temperature, eos, remaining) -> Dict[str, jnp.ndarray]:
    """Write one admitted request's row into the device decode state.

    Pure (jit with donated `state` by the engine): slot may be a traced
    int32. eos < 0 means no EOS; remaining <= 0 installs an inactive row
    (request finished at prefill)."""
    return {
        "tokens": state["tokens"].at[slot].set(token),
        "index": state["index"].at[slot].set(index),
        "key": state["key"],
        "temperature": state["temperature"].at[slot].set(temperature),
        "eos": state["eos"].at[slot].set(eos),
        "remaining": state["remaining"].at[slot].set(remaining),
        "active": state["active"].at[slot].set(remaining > 0),
    }
